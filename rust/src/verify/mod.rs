//! `cli verify` — offline fsck over a tree of archives and streams.
//!
//! Walks a root directory, classifies every file by magic (`ARDC`
//! archive / `TSTR` stream — anything else is ignored, a data root
//! holds raw fields too), and validates framing, checksums, block
//! indices, and timelines:
//!
//! * **archives** are atomic: they either parse fully (XSUM trailer
//!   verified when the header declares one, strict trailing-byte check
//!   otherwise) or they are corrupt. There is nothing to repair — a
//!   damaged archive is quarantined under `--repair`.
//! * **streams** are append-only, so damage has structure: a *torn
//!   tail* (crash mid-append, or a broken seal) is recoverable by
//!   truncating to the end of the last complete, well-formed step
//!   record — exactly what [`crate::stream::StreamWriter::reopen`]
//!   would keep. `--repair` performs that truncation (fsynced). A
//!   stream whose header or header-pinning `XSUM` record is damaged
//!   has no trustworthy framing at all and is quarantined.
//!
//! Default mode is strictly read-only — CI runs `cli verify --root
//! tests/golden` and then asserts the corpus is byte-identical.
//! Quarantine renames `f` to `f.quarantine` in place (same directory,
//! nothing deleted); `.quarantine` files and dotfiles (including the
//! durability layer's temp siblings) are skipped on later runs.

use std::path::{Path, PathBuf};

use crate::compressor::format::{
    parse_stream_header, parse_stream_record, parse_stream_record_checked, STREAM_KEY_TAG,
    STREAM_MAGIC, STREAM_RES_TAG, STREAM_TIDX_TAG, STREAM_XSUM_TAG, XSUM_HEADER_KEY,
};
use crate::compressor::Archive;
use crate::stream::TimelineIndex;
use crate::util::{crc32c, durable};
use crate::Result;
use anyhow::Context;

const ARCHIVE_MAGIC: &[u8; 4] = b"ARDC";

/// What verification concluded about one file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Status {
    Clean,
    /// The file is valid up to `recover_len` bytes (the end of the last
    /// complete step record); everything after is a torn or damaged
    /// tail that truncation repairs.
    Torn { recover_len: u64, steps_kept: usize, tail_bytes: usize },
    /// No recoverable structure (or an atomic archive that failed) —
    /// quarantined under `--repair`.
    Corrupt(String),
}

/// What `--repair` did to the file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    None,
    Repaired,
    Quarantined(PathBuf),
    /// Repair was attempted but failed (I/O error) — reported, file
    /// left as-is.
    Failed(String),
}

#[derive(Debug)]
pub struct FileReport {
    pub path: PathBuf,
    /// "archive" | "stream".
    pub kind: &'static str,
    /// Human summary: version, checksumming, step counts.
    pub detail: String,
    pub status: Status,
    pub action: Action,
}

#[derive(Debug, Default)]
pub struct VerifyReport {
    pub files: Vec<FileReport>,
    pub clean: usize,
    pub torn: usize,
    pub corrupt: usize,
    pub repaired: usize,
    pub quarantined: usize,
}

impl VerifyReport {
    /// Everything either verified clean or was repaired back to clean.
    pub fn all_ok(&self) -> bool {
        self.files.iter().all(|f| {
            matches!(f.status, Status::Clean) || matches!(f.action, Action::Repaired)
        })
    }
}

/// Deep-check one archive: full parse (XSUM verified when declared,
/// strict trailing bytes otherwise), block-index parse, and for v2
/// containers a recursive check of every embedded field archive.
fn check_archive(bytes: &[u8]) -> Result<String> {
    let a = Archive::from_bytes(bytes)?;
    if a.version() == 2 {
        for i in 0..a.field_count() {
            let sub = a.field_archive(i).with_context(|| format!("field {i}"))?;
            sub.block_index().with_context(|| format!("field {i} block index"))?;
        }
    } else {
        a.block_index()?;
    }
    Ok(format!(
        "v{}{}, {} sections",
        a.version(),
        if a.checksummed() { ", checksummed" } else { "" },
        a.section_sizes().len()
    ))
}

/// Walk one stream's records and classify it. Returns `(detail,
/// status)` — never errors: every failure mode maps to a [`Status`].
fn check_stream(bytes: &[u8]) -> (String, Status) {
    let (header, hdr_end) = match parse_stream_header(bytes) {
        Ok(v) => v,
        Err(e) => return ("stream".into(), Status::Corrupt(format!("{e:#}"))),
    };
    let keyint = match header.get("keyint").and_then(|v| v.as_usize()).filter(|&k| k >= 1) {
        Some(k) => k,
        None => {
            return ("stream".into(), Status::Corrupt("header keyint missing or invalid".into()))
        }
    };
    let checked = header.get(XSUM_HEADER_KEY).is_some();
    let detail_base = if checked { "stream, checksummed" } else { "stream" };
    // a checked stream's header is pinned by the XSUM record; if that
    // fails there is no trustworthy framing to recover from
    let mut off = hdr_end;
    if checked {
        match parse_stream_record_checked(bytes, off) {
            Ok((tag, p, len, next)) if &tag == STREAM_XSUM_TAG && len == 4 => {
                let stored = u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap());
                if crc32c::crc32c(&bytes[..hdr_end]) != stored {
                    return (
                        detail_base.into(),
                        Status::Corrupt("header checksum mismatch".into()),
                    );
                }
                off = next;
            }
            _ => {
                return (
                    detail_base.into(),
                    Status::Corrupt("header XSUM record missing or damaged".into()),
                )
            }
        }
    }
    let parse = |off: usize| {
        if checked {
            parse_stream_record_checked(bytes, off)
        } else {
            parse_stream_record(bytes, off)
        }
    };
    let mut steps = 0usize;
    let torn = |at: usize, steps: usize| Status::Torn {
        recover_len: at as u64,
        steps_kept: steps,
        tail_bytes: bytes.len() - at,
    };
    loop {
        let Ok((tag, p, len, next)) = parse(off) else {
            // torn tail: truncation mid-record, or (checked) a record
            // failing its CRC — either way the file is good up to `off`
            break if off == bytes.len() {
                (format!("{detail_base}, {steps} steps, unsealed"), Status::Clean)
            } else {
                (format!("{detail_base}, {steps} steps"), torn(off, steps))
            };
        };
        let keyframe = match &tag {
            t if t == STREAM_KEY_TAG => true,
            t if t == STREAM_RES_TAG => false,
            t if t == STREAM_TIDX_TAG => {
                // candidate seal: exactly TIDX + 12-byte footer ending
                // the file, the footer pointing back at this record,
                // and a timeline consistent with the records walked
                let sealed = bytes.len() == next + 12
                    && &bytes[next + 8..next + 12] == b"TEND"
                    && u64::from_le_bytes(bytes[next..next + 8].try_into().unwrap())
                        == off as u64
                    && TimelineIndex::from_bytes(&bytes[p..p + len])
                        .map(|idx| {
                            idx.keyframe_interval as usize == keyint
                                && idx.n_steps() == steps
                                && idx.validate(bytes.len() as u64).is_ok()
                        })
                        .unwrap_or(false);
                break if sealed {
                    (format!("{detail_base}, {steps} steps, sealed"), Status::Clean)
                } else {
                    // broken seal: the steps are fine — truncating to
                    // the start of the TIDX record re-opens the stream
                    (format!("{detail_base}, {steps} steps"), torn(off, steps))
                };
            }
            // an unknown record tag mid-stream: nothing after it is
            // trustworthy, the steps before it are
            _ => break (format!("{detail_base}, {steps} steps"), torn(off, steps)),
        };
        if steps == 0 && !keyframe {
            break (
                detail_base.into(),
                Status::Corrupt("step 0 is not a keyframe".into()),
            );
        }
        // each step embeds a complete archive; in legacy (un-CRC'd)
        // streams this parse is the only integrity check there is —
        // a bad step archive truncates the stream just before it
        if Archive::from_bytes(&bytes[p..p + len]).is_err() {
            break (format!("{detail_base}, {steps} steps"), torn(off, steps));
        }
        steps += 1;
        off = next;
    }
}

/// Verify one file in place (read-only). `None` when the file is not a
/// container this repo owns (wrong magic, unreadable, dotfile).
pub fn verify_file(path: &Path) -> Option<FileReport> {
    let name = path.file_name()?.to_string_lossy().into_owned();
    if name.starts_with('.') || name.ends_with(".quarantine") {
        return None;
    }
    let bytes = std::fs::read(path).ok()?;
    if bytes.len() < 4 {
        return None;
    }
    let (kind, detail, status) = if &bytes[0..4] == ARCHIVE_MAGIC {
        match check_archive(&bytes) {
            Ok(detail) => ("archive", detail, Status::Clean),
            Err(e) => ("archive", "archive".to_string(), Status::Corrupt(format!("{e:#}"))),
        }
    } else if &bytes[0..4] == STREAM_MAGIC {
        let (detail, status) = check_stream(&bytes);
        ("stream", detail, status)
    } else {
        return None;
    };
    Some(FileReport { path: path.to_path_buf(), kind, detail, status, action: Action::None })
}

fn apply_repair(report: &mut FileReport) {
    match &report.status {
        Status::Clean => {}
        Status::Torn { recover_len, .. } => {
            let res = (|| -> std::io::Result<()> {
                let f = std::fs::OpenOptions::new().write(true).open(&report.path)?;
                f.set_len(*recover_len)?;
                f.sync_all()?;
                if let Some(dir) = report.path.parent() {
                    durable::fsync_dir(dir)?;
                }
                Ok(())
            })();
            report.action = match res {
                Ok(()) => Action::Repaired,
                Err(e) => Action::Failed(e.to_string()),
            };
        }
        Status::Corrupt(_) => {
            let mut q = report.path.as_os_str().to_os_string();
            q.push(".quarantine");
            let q = PathBuf::from(q);
            report.action = match std::fs::rename(&report.path, &q) {
                Ok(()) => Action::Quarantined(q),
                Err(e) => Action::Failed(e.to_string()),
            };
        }
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else {
            out.push(p);
        }
    }
    Ok(())
}

/// Verify every archive/stream under `root` (deterministic order).
/// With `repair`, torn streams are truncated to their last complete
/// record and unrecoverable files are quarantined; without it the walk
/// is strictly read-only.
pub fn verify_root(root: &Path, repair: bool) -> Result<VerifyReport> {
    let mut paths = Vec::new();
    if root.is_dir() {
        walk(root, &mut paths)?;
    } else {
        paths.push(root.to_path_buf());
    }
    let mut report = VerifyReport::default();
    for p in paths {
        let Some(mut file) = verify_file(&p) else { continue };
        match &file.status {
            Status::Clean => report.clean += 1,
            Status::Torn { .. } => report.torn += 1,
            Status::Corrupt(_) => report.corrupt += 1,
        }
        if repair {
            apply_repair(&mut file);
            match &file.action {
                Action::Repaired => report.repaired += 1,
                Action::Quarantined(_) => report.quarantined += 1,
                _ => {}
            }
        }
        report.files.push(file);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::format::stream_record_bytes;
    use crate::util::json;

    fn tmp_root(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("attn_verify_{name}"));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn small_archive() -> Archive {
        let mut a = Archive::new(json::obj(vec![("codec", json::s("sz3"))]));
        a.add_section("SZ3B", vec![1, 2, 3, 4]);
        a
    }

    #[test]
    fn clean_checked_archives_verify_clean_and_stay_untouched() {
        let d = tmp_root("arch_ok");
        let p = d.join("a.ardc");
        small_archive().save(&p).unwrap();
        let before = std::fs::read(&p).unwrap();
        let rep = verify_root(&d, false).unwrap();
        assert_eq!(rep.clean, 1);
        assert_eq!((rep.torn, rep.corrupt), (0, 0));
        assert!(rep.all_ok());
        assert!(rep.files[0].detail.contains("checksummed"), "{}", rep.files[0].detail);
        assert_eq!(std::fs::read(&p).unwrap(), before, "read-only");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn every_flip_in_a_checked_archive_is_detected_and_quarantined() {
        let d = tmp_root("arch_flip");
        let p = d.join("a.ardc");
        small_archive().save(&p).unwrap();
        let good = std::fs::read(&p).unwrap();
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x04;
            std::fs::write(&p, &bad).unwrap();
            let rep = verify_root(&d, false).unwrap();
            // a magic-byte flip makes the file unrecognizable (skipped);
            // every other flip must classify as corrupt — never clean
            assert_eq!(rep.clean, 0, "flip at byte {i} verified clean");
            if i >= 4 {
                assert_eq!(rep.corrupt, 1, "flip at byte {i} not detected");
            }
        }
        std::fs::write(&p, &good).unwrap();
        // repair mode quarantines, and a rerun skips the quarantined file
        let mut bad = good.clone();
        bad[good.len() - 20] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        let rep = verify_root(&d, true).unwrap();
        assert_eq!(rep.quarantined, 1);
        assert!(!p.exists());
        assert!(d.join("a.ardc.quarantine").exists());
        let rep = verify_root(&d, false).unwrap();
        assert!(rep.files.is_empty(), "quarantined files are skipped");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn legacy_archives_and_foreign_files_are_handled() {
        let d = tmp_root("legacy");
        // legacy (unchecksummed) bytes written directly
        std::fs::write(d.join("old.ardc"), small_archive().to_bytes()).unwrap();
        // not a container: ignored entirely
        std::fs::write(d.join("data.f32"), [0u8; 64]).unwrap();
        std::fs::write(d.join("tiny"), [1u8; 2]).unwrap();
        let rep = verify_root(&d, false).unwrap();
        assert_eq!(rep.clean, 1);
        assert_eq!(rep.files.len(), 1);
        assert!(!rep.files[0].detail.contains("checksummed"));
        std::fs::remove_dir_all(&d).ok();
    }

    fn synth_stream(steps: usize, seal: bool) -> Vec<u8> {
        // a hand-framed legacy (un-CRC'd) stream embedding real archives;
        // check_stream only reads `keyint` and the xsum flag
        let header =
            json::obj(vec![("codec", json::s("sz3")), ("keyint", json::num(2.0))]);
        let mut out = crate::compressor::format::stream_header_bytes(&header);
        let mut entries = Vec::new();
        for s in 0..steps {
            let payload = small_archive().to_bytes();
            let tag = if s % 2 == 0 { STREAM_KEY_TAG } else { STREAM_RES_TAG };
            entries.push(crate::stream::StepEntry {
                keyframe: s % 2 == 0,
                offset: (out.len() + 12) as u64,
                len: payload.len() as u64,
            });
            out.extend_from_slice(&stream_record_bytes(tag, &payload));
        }
        if seal {
            let idx = TimelineIndex { keyframe_interval: 2, entries };
            let tidx_off = out.len() as u64;
            out.extend_from_slice(&stream_record_bytes(STREAM_TIDX_TAG, &idx.to_bytes()));
            out.extend_from_slice(&tidx_off.to_le_bytes());
            out.extend_from_slice(b"TEND");
        }
        out
    }

    #[test]
    fn streams_classify_as_sealed_unsealed_or_torn() {
        let d = tmp_root("streams");
        std::fs::write(d.join("sealed.tstr"), synth_stream(3, true)).unwrap();
        std::fs::write(d.join("unsealed.tstr"), synth_stream(3, false)).unwrap();
        // torn: an unsealed stream cut mid-record
        let full = synth_stream(3, false);
        std::fs::write(d.join("torn.tstr"), &full[..full.len() - 5]).unwrap();
        let rep = verify_root(&d, false).unwrap();
        assert_eq!((rep.clean, rep.torn, rep.corrupt), (2, 1, 0));
        let torn = rep.files.iter().find(|f| f.path.ends_with("torn.tstr")).unwrap();
        let Status::Torn { steps_kept, .. } = torn.status else {
            panic!("expected torn: {:?}", torn.status)
        };
        assert_eq!(steps_kept, 2);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn repair_truncates_torn_tails_back_to_clean() {
        let d = tmp_root("repair");
        let full = synth_stream(4, true);
        let p = d.join("s.tstr");
        // cut inside the seal: steps survive, the seal does not
        std::fs::write(&p, &full[..full.len() - 3]).unwrap();
        let rep = verify_root(&d, true).unwrap();
        assert_eq!(rep.repaired, 1);
        assert!(rep.all_ok());
        // the repaired stream verifies clean (unsealed) and kept steps
        let rep = verify_root(&d, false).unwrap();
        assert_eq!(rep.clean, 1);
        assert!(rep.files[0].detail.contains("4 steps"), "{}", rep.files[0].detail);
        std::fs::remove_dir_all(&d).ok();
    }
}
