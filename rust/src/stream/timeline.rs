//! [`TimelineIndex`] — the `TIDX` section of a v4 temporal stream: step
//! id → keyframe flag + byte span of that step's embedded archive.
//!
//! Entry *i* describes step *i* (steps are dense, starting at 0). The
//! span points at the step archive's payload bytes inside the stream
//! file (past the 12-byte record header), so random access is one index
//! lookup plus one `Archive::from_bytes` per chain step — and each step
//! archive carries its own `BIDX` block index, giving the second level
//! of granularity for `(step, region)` decodes.

use crate::Result;
use anyhow::ensure;

/// One step's index entry: keyframe flag + byte span of its archive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepEntry {
    pub keyframe: bool,
    /// Byte offset of the step archive inside the stream file.
    pub offset: u64,
    /// Byte length of the step archive.
    pub len: u64,
}

/// The v4 timeline index.
///
/// Serialized layout (little-endian, record `TIDX`):
/// ```text
///   u32 keyframe_interval | u64 n_steps | n x (u8 flag, u64 off, u64 len)
/// ```
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TimelineIndex {
    /// The writer's keyframe cadence (step `i` is a keyframe when
    /// `i % K == 0`); informational — the per-entry flags are
    /// authoritative.
    pub keyframe_interval: u32,
    pub entries: Vec<StepEntry>,
}

impl TimelineIndex {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.entries.len() * 17);
        out.extend_from_slice(&self.keyframe_interval.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for e in &self.entries {
            out.push(e.keyframe as u8);
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.len.to_le_bytes());
        }
        out
    }

    /// Parse a `TIDX` payload. Untrusted input: the declared entry count
    /// is capped by the bytes actually present (17 B per entry) before
    /// it sizes an allocation, and flag bytes must be 0/1.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 12, "timeline index truncated");
        let keyframe_interval = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        ensure!(keyframe_interval >= 1, "timeline keyframe interval is zero");
        let n = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
        let n = usize::try_from(n)
            .map_err(|_| anyhow::anyhow!("timeline entry count overflow"))?;
        ensure!(
            n <= (bytes.len() - 12) / 17,
            "timeline declares {n} steps, impossible in {} bytes",
            bytes.len()
        );
        let mut entries = Vec::with_capacity(n);
        let mut off = 12usize;
        for i in 0..n {
            let flag = bytes[off];
            ensure!(flag <= 1, "timeline step {i} has flag byte {flag}");
            let o = u64::from_le_bytes(bytes[off + 1..off + 9].try_into().unwrap());
            let l = u64::from_le_bytes(bytes[off + 9..off + 17].try_into().unwrap());
            entries.push(StepEntry { keyframe: flag == 1, offset: o, len: l });
            off += 17;
        }
        ensure!(off == bytes.len(), "timeline index has trailing bytes");
        Ok(Self { keyframe_interval, entries })
    }

    /// Check every span lies inside `file_len` and the first step is a
    /// keyframe (a residual with no base frame is undecodable).
    pub fn validate(&self, file_len: u64) -> Result<()> {
        if let Some(first) = self.entries.first() {
            ensure!(first.keyframe, "timeline step 0 is not a keyframe");
        }
        for (i, e) in self.entries.iter().enumerate() {
            let end = e
                .offset
                .checked_add(e.len)
                .ok_or_else(|| anyhow::anyhow!("timeline step {i} extent overflow"))?;
            ensure!(
                end <= file_len,
                "timeline step {i} extent {}+{} exceeds file {file_len}",
                e.offset,
                e.len
            );
        }
        Ok(())
    }

    pub fn n_steps(&self) -> usize {
        self.entries.len()
    }

    /// The nearest keyframe at or before `step` — the base of `step`'s
    /// residual chain.
    pub fn keyframe_for(&self, step: usize) -> Result<usize> {
        ensure!(step < self.entries.len(), "step {step} out of range ({} steps)", self.entries.len());
        (0..=step)
            .rev()
            .find(|&s| self.entries[s].keyframe)
            .ok_or_else(|| anyhow::anyhow!("no keyframe at or before step {step}"))
    }

    /// The steps a decode of `step` must touch: the chain
    /// `keyframe..=step`.
    pub fn chain(&self, step: usize) -> Result<std::ops::RangeInclusive<usize>> {
        Ok(self.keyframe_for(step)?..=step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TimelineIndex {
        TimelineIndex {
            keyframe_interval: 3,
            entries: vec![
                StepEntry { keyframe: true, offset: 22, len: 100 },
                StepEntry { keyframe: false, offset: 134, len: 40 },
                StepEntry { keyframe: false, offset: 186, len: 41 },
                StepEntry { keyframe: true, offset: 239, len: 99 },
                StepEntry { keyframe: false, offset: 350, len: 38 },
            ],
        }
    }

    #[test]
    fn round_trips_and_validates() {
        let idx = sample();
        let back = TimelineIndex::from_bytes(&idx.to_bytes()).unwrap();
        assert_eq!(back, idx);
        back.validate(388).unwrap();
        assert!(back.validate(387).is_err(), "extent past file end");
        assert_eq!(back.n_steps(), 5);
    }

    #[test]
    fn keyframe_chain_resolution() {
        let idx = sample();
        assert_eq!(idx.keyframe_for(0).unwrap(), 0);
        assert_eq!(idx.keyframe_for(2).unwrap(), 0);
        assert_eq!(idx.keyframe_for(3).unwrap(), 3);
        assert_eq!(idx.keyframe_for(4).unwrap(), 3);
        assert_eq!(idx.chain(2).unwrap(), 0..=2);
        assert_eq!(idx.chain(4).unwrap(), 3..=4);
        assert!(idx.keyframe_for(5).is_err(), "out of range");
    }

    #[test]
    fn rejects_corrupt_input() {
        let bytes = sample().to_bytes();
        for cut in 0..bytes.len() {
            assert!(TimelineIndex::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // absurd entry count must not allocate
        let mut b = bytes.clone();
        b[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(TimelineIndex::from_bytes(&b).is_err());
        // non-boolean flag byte
        let mut b = bytes.clone();
        b[12] = 7;
        assert!(TimelineIndex::from_bytes(&b).is_err());
        // zero keyframe interval
        let mut b = bytes;
        b[0..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(TimelineIndex::from_bytes(&b).is_err());
        // a stream whose first step is a residual has no decodable base
        let orphan = TimelineIndex {
            keyframe_interval: 2,
            entries: vec![StepEntry { keyframe: false, offset: 22, len: 10 }],
        };
        assert!(orphan.validate(1000).is_err());
    }
}
