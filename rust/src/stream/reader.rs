//! [`StreamReader`] — random access and playback over v4 temporal
//! streams.
//!
//! Random access by `(step, region)` decodes the *chain* of `step`: the
//! nearest keyframe at or before it plus every residual up to it — and
//! for a region, only the blocks each chain archive's `BIDX` says the
//! region intersects (`Codec::decompress_region` per step). The result
//! is bit-identical to cropping a full-frame decode, and
//! [`StreamReader::region_cost`] accounts exactly which payload bytes a
//! region decode touches so tests (and capacity planning) can verify
//! the locality claim.

use std::path::Path;

use crate::codec::{archive_bound, Codec, CodecBuilder, ErrorBound};
use crate::compressor::format::{
    corrupt, parse_stream_header, parse_stream_record, parse_stream_record_checked,
    STREAM_END_MAGIC, STREAM_KEY_TAG, STREAM_RES_TAG, STREAM_TIDX_TAG, STREAM_XSUM_TAG,
    XSUM_HEADER_KEY,
};
use crate::compressor::{compression_ratio, Archive};
use crate::config::DatasetConfig;
use crate::data::{region_tile_ids, Region};
use crate::tensor::Tensor;
use crate::util::crc32c;
use crate::util::json::Value;
use crate::Result;
use anyhow::{ensure, Context};

use super::residual::add_residual;
use super::timeline::{StepEntry, TimelineIndex};

/// Exactly what a `(step, region)` decode touches, in payload bytes and
/// blocks, summed over the chain `keyframe..=step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionCost {
    /// Chain length (keyframe + residuals decoded).
    pub steps: usize,
    pub blocks_touched: usize,
    pub blocks_total: usize,
    pub bytes_touched: usize,
    pub bytes_total: usize,
}

/// Compression statistics of a whole stream.
#[derive(Debug, Clone, Copy)]
pub struct StreamStats {
    pub steps: usize,
    pub keyframes: usize,
    /// Summed CR-payload bytes across step archives (paper accounting).
    pub payload_bytes: usize,
    /// The whole file, framing included.
    pub file_bytes: usize,
    pub cr: f64,
    pub cr_total: f64,
}

/// A shareable reader handle: a [`StreamReader`] is immutable after
/// open (plain data + parsed index), so concurrent `(step, region)`
/// decodes need no locking — the serving layer clones one `Arc` per
/// request.
pub type SharedReader = std::sync::Arc<StreamReader>;

/// Read-side view of one v4 stream.
pub struct StreamReader {
    bytes: Vec<u8>,
    header: Value,
    records_start: usize,
    dataset: DatasetConfig,
    bound: ErrorBound,
    codec_id: String,
    index: TimelineIndex,
    finished: bool,
    /// Checked framing (`"xsum": 1` header): the header is pinned by an
    /// `XSUM` record and every record carries a trailing CRC32C.
    checked: bool,
}

impl StreamReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading stream {}", path.display()))?;
        Self::from_bytes(bytes)
    }

    /// Parse a stream from its bytes. A sealed stream (footer present)
    /// loads its `TIDX` timeline directly; an unsealed one — a crashed
    /// or still-growing producer — recovers the timeline by scanning
    /// complete step records, dropping any torn tail.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        let (header, records_start) = parse_stream_header(&bytes)?;
        let codec_id = header
            .req("codec")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("stream header codec is not a string"))?
            .to_string();
        let dataset = DatasetConfig::from_json(header.req("dataset")?)?;
        let bound = ErrorBound::from_json(header.req("bound")?)?;
        let keyint = header
            .req("keyint")?
            .as_usize()
            .filter(|&k| k >= 1)
            .ok_or_else(|| anyhow::anyhow!("stream header keyint is not a positive integer"))?;
        // checked streams pin their header bytes under the XSUM record
        // right after the header; step records begin past it
        let checked = header.get(XSUM_HEADER_KEY).is_some();
        let records_start = if checked {
            let (tag, p, len, next) = parse_stream_record_checked(&bytes, records_start)
                .context("stream declares checksums but its XSUM record is damaged")?;
            if &tag != STREAM_XSUM_TAG || len != 4 {
                return Err(corrupt("stream XSUM record malformed"));
            }
            let stored = u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap());
            if crc32c::crc32c(&bytes[..records_start]) != stored {
                return Err(corrupt("stream header checksum mismatch"));
            }
            next
        } else {
            records_start
        };
        // prefer the sealed-stream TIDX; on any footer/index corruption
        // fall back to the recovery scan (which trusts only complete,
        // well-formed records), so a damaged seal degrades instead of
        // bricking the stream
        let footer = Self::footer_index(&bytes, records_start, checked).filter(|idx| {
            idx.keyframe_interval as usize == keyint
                && idx.validate(bytes.len() as u64).is_ok()
        });
        let (index, finished) = match footer {
            Some(idx) => (idx, true),
            None => {
                let idx = Self::scan_index(&bytes, records_start, keyint, checked);
                idx.validate(bytes.len() as u64)?;
                (idx, false)
            }
        };
        Ok(Self {
            bytes,
            header,
            records_start,
            dataset,
            bound,
            codec_id,
            index,
            finished,
            checked,
        })
    }

    /// The sealed-stream path: footer → `TIDX` record → timeline.
    /// `None` on any inconsistency — the caller falls back to scanning.
    fn footer_index(bytes: &[u8], records_start: usize, checked: bool) -> Option<TimelineIndex> {
        if bytes.len() < records_start + 12 {
            return None;
        }
        let foot = &bytes[bytes.len() - 12..];
        if &foot[8..12] != STREAM_END_MAGIC {
            return None;
        }
        let off = u64::from_le_bytes(foot[0..8].try_into().unwrap());
        let off = usize::try_from(off)
            .ok()
            .filter(|&o| o >= records_start && o < bytes.len())?;
        let (tag, p, len, _) = if checked {
            parse_stream_record_checked(bytes, off).ok()?
        } else {
            parse_stream_record(bytes, off).ok()?
        };
        if &tag != STREAM_TIDX_TAG {
            return None;
        }
        TimelineIndex::from_bytes(&bytes[p..p + len]).ok()
    }

    /// Recovery scan: walk complete records from the header, keeping
    /// every well-formed step, stopping at the first torn or non-step
    /// record. Never errors — a truncated tail just yields fewer steps,
    /// and in a checked stream a record failing its CRC ends the scan
    /// the same way (`cli verify` distinguishes torn from corrupt).
    fn scan_index(
        bytes: &[u8],
        records_start: usize,
        keyint: usize,
        checked: bool,
    ) -> TimelineIndex {
        let mut entries = Vec::new();
        let mut off = records_start;
        loop {
            let parsed = if checked {
                parse_stream_record_checked(bytes, off)
            } else {
                parse_stream_record(bytes, off)
            };
            let Ok((tag, p, len, next)) = parsed else { break };
            let keyframe = match &tag {
                t if t == STREAM_KEY_TAG => true,
                t if t == STREAM_RES_TAG => false,
                _ => break,
            };
            entries.push(StepEntry { keyframe, offset: p as u64, len: len as u64 });
            off = next;
        }
        TimelineIndex { keyframe_interval: keyint as u32, entries }
    }

    pub fn n_steps(&self) -> usize {
        self.index.n_steps()
    }

    pub fn keyframe_interval(&self) -> usize {
        self.index.keyframe_interval as usize
    }

    pub fn dataset(&self) -> &DatasetConfig {
        &self.dataset
    }

    pub fn bound(&self) -> ErrorBound {
        self.bound
    }

    pub fn codec_id(&self) -> &str {
        &self.codec_id
    }

    /// Was the stream sealed by `finish()` (vs timeline recovered by
    /// scanning)?
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Does this stream use checked (CRC-per-record) framing?
    pub fn is_checksummed(&self) -> bool {
        self.checked
    }

    pub fn timeline(&self) -> &TimelineIndex {
        &self.index
    }

    pub fn header(&self) -> &Value {
        &self.header
    }

    /// Byte offset where step records begin — just past the header, and
    /// in a checked stream also past the header-pinning `XSUM` record.
    pub fn records_start(&self) -> usize {
        self.records_start
    }

    /// Size of the backing file in bytes (cache cost accounting).
    pub fn file_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// The keyframe step at the base of `step`'s residual chain.
    pub fn keyframe_step(&self, step: usize) -> Result<usize> {
        self.index.keyframe_for(step)
    }

    /// Parse the embedded archive of one step. In a checked stream the
    /// record's CRC is verified first (lazily, per access), so a flipped
    /// byte in a sealed stream surfaces as typed corruption even though
    /// the timeline index loaded without walking the records.
    pub fn step_archive(&self, step: usize) -> Result<Archive> {
        let e = self
            .index
            .entries
            .get(step)
            .ok_or_else(|| anyhow::anyhow!("step {step} out of range ({} steps)", self.n_steps()))?;
        let (off, len) = (e.offset as usize, e.len as usize);
        if self.checked {
            let rec = off
                .checked_sub(12)
                .ok_or_else(|| corrupt(format!("step {step} record offset inside header")))?;
            let crc_end = off + len + 4;
            if self.bytes.len() < crc_end {
                return Err(corrupt(format!("step {step} record checksum truncated")));
            }
            let stored =
                u32::from_le_bytes(self.bytes[off + len..crc_end].try_into().unwrap());
            if crc32c::crc32c(&self.bytes[rec..off + len]) != stored {
                return Err(corrupt(format!("step {step} record failed its checksum")));
            }
        }
        Archive::from_bytes(&self.bytes[off..off + len])
            .with_context(|| format!("parsing step {step} archive"))
    }

    /// Rebuild the stream's codec from its first step archive (steps are
    /// self-describing, and all steps share codec, dataset, and model
    /// groups). Requires at least one step.
    pub fn build_codec(&self, builder: &mut CodecBuilder) -> Result<Box<dyn Codec>> {
        ensure!(self.n_steps() > 0, "stream holds no steps yet");
        builder.for_archive(&self.step_archive(0)?)
    }

    /// Decode the absolute frame at `step`: the nearest keyframe plus
    /// every residual up to `step`, summed in chain order.
    pub fn frame(&self, codec: &dyn Codec, step: usize) -> Result<Tensor> {
        let _span = crate::obs::stages::STREAM_EXTRACT.span();
        let chain = self.index.chain(step)?;
        let mut recon: Option<Tensor> = None;
        for s in chain {
            let dec = codec.decompress(&self.step_archive(s)?)?;
            recon = Some(match recon {
                None => dec,
                Some(prev) => add_residual(&prev, &dec),
            });
        }
        Ok(recon.expect("chain is non-empty"))
    }

    /// Decode only `region` of the frame at `step`: every chain archive
    /// decodes just the blocks the region intersects (via its `BIDX`),
    /// and the partial frames sum in the same order as [`Self::frame`] —
    /// so the result is bit-identical to cropping the full decode.
    pub fn extract(&self, codec: &dyn Codec, step: usize, region: &Region) -> Result<Tensor> {
        let _span = crate::obs::stages::STREAM_EXTRACT.span();
        region.validate_in(&self.dataset.dims)?;
        let chain = self.index.chain(step)?;
        let mut recon: Option<Tensor> = None;
        for s in chain {
            let dec = codec.decompress_region(&self.step_archive(s)?, region)?;
            recon = Some(match recon {
                None => dec,
                Some(prev) => add_residual(&prev, &dec),
            });
        }
        Ok(recon.expect("chain is non-empty"))
    }

    /// [`Self::extract`] resumed from an already-decoded base: `base`
    /// must be the decode of `(base_step, region)` where `base_step` is
    /// `step`'s keyframe (see [`Self::keyframe_step`]) — the serving
    /// layer caches decoded keyframe regions and replays only the
    /// residual tail through here. Summing the same archives in the
    /// same order keeps the result bit-identical to a cold
    /// [`Self::extract`].
    pub fn extract_from(
        &self,
        codec: &dyn Codec,
        base: &Tensor,
        base_step: usize,
        step: usize,
        region: &Region,
    ) -> Result<Tensor> {
        let _span = crate::obs::stages::STREAM_EXTRACT.span();
        region.validate_in(&self.dataset.dims)?;
        ensure!(
            self.index.keyframe_for(step)? == base_step,
            "base step {base_step} is not the keyframe of step {step}"
        );
        let mut recon = base.clone();
        for s in base_step + 1..=step {
            let dec = codec.decompress_region(&self.step_archive(s)?, region)?;
            recon = add_residual(&recon, &dec);
        }
        Ok(recon)
    }

    /// Account exactly what a `(step, region)` decode touches: per chain
    /// archive, the indexed byte spans of the intersecting blocks (a
    /// v1-style step without a block index counts fully — it can only
    /// decode whole).
    pub fn region_cost(&self, step: usize, region: &Region) -> Result<RegionCost> {
        region.validate_in(&self.dataset.dims)?;
        let chain = self.index.chain(step)?;
        let mut cost = RegionCost {
            steps: 0,
            blocks_touched: 0,
            blocks_total: 0,
            bytes_touched: 0,
            bytes_total: 0,
        };
        for s in chain {
            let archive = self.step_archive(s)?;
            cost.steps += 1;
            match archive.block_index()? {
                Some(idx) => {
                    let ids = region_tile_ids(&self.dataset.dims, &idx.tile, region);
                    cost.blocks_touched += ids.len();
                    cost.blocks_total += idx.entries.len();
                    cost.bytes_touched += idx.bytes_for(&ids);
                    cost.bytes_total += idx.total_bytes();
                }
                None => {
                    let b = archive.cr_payload_bytes();
                    cost.blocks_touched += 1;
                    cost.blocks_total += 1;
                    cost.bytes_touched += b;
                    cost.bytes_total += b;
                }
            }
        }
        Ok(cost)
    }

    /// In-order playback: decodes each step once, carrying the running
    /// reconstruction (keyframes reset it), so a full pass costs one
    /// decode per step instead of one chain per step.
    pub fn frames<'a>(&'a self, codec: &'a dyn Codec) -> FrameIter<'a> {
        FrameIter { reader: self, codec, next: 0, prev: None }
    }

    /// Stream-level compression statistics (paper accounting: summed
    /// step payload sections; numerator = points × steps).
    pub fn stats(&self) -> Result<StreamStats> {
        let mut payload = 0usize;
        let mut keyframes = 0usize;
        for s in 0..self.n_steps() {
            payload += self.step_archive(s)?.cr_payload_bytes();
            keyframes += self.index.entries[s].keyframe as usize;
        }
        let n_points = self.dataset.total_points() * self.n_steps();
        Ok(StreamStats {
            steps: self.n_steps(),
            keyframes,
            payload_bytes: payload,
            file_bytes: self.bytes.len(),
            cr: compression_ratio(n_points, payload),
            cr_total: compression_ratio(n_points, self.bytes.len()),
        })
    }

    /// The bound a given step archive was written under (keyframes carry
    /// the stream bound; residuals the translated residual bound).
    pub fn step_bound(&self, step: usize) -> Result<ErrorBound> {
        Ok(archive_bound(&self.step_archive(step)?))
    }
}

/// Iterator over absolute frames in step order (see
/// [`StreamReader::frames`]). Yields `Result<Tensor>`; a decode error
/// ends iteration after being reported once.
pub struct FrameIter<'a> {
    reader: &'a StreamReader,
    codec: &'a dyn Codec,
    next: usize,
    prev: Option<Tensor>,
}

impl Iterator for FrameIter<'_> {
    type Item = Result<Tensor>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.reader.n_steps() {
            return None;
        }
        let step = self.next;
        let out: Result<Tensor> = (|| {
            let entry = self.reader.index.entries[step];
            let dec = self.codec.decompress(&self.reader.step_archive(step)?)?;
            let recon = if entry.keyframe {
                dec
            } else {
                let prev = self
                    .prev
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("residual step {step} has no base frame"))?;
                add_residual(prev, &dec)
            };
            Ok(recon)
        })();
        match out {
            Ok(recon) => {
                self.prev = Some(recon.clone());
                self.next += 1;
                Some(Ok(recon))
            }
            Err(e) => {
                self.next = self.reader.n_steps(); // stop after the error
                Some(Err(e))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The serving layer shares one reader across pool threads; this
    /// pins the auto-trait guarantee at compile time.
    #[test]
    fn reader_handles_are_shareable_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<StreamReader>();
        assert_send_sync::<SharedReader>();
    }
}
