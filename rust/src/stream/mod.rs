//! Temporal stream subsystem: append-only time-series archives with
//! keyframe/residual coding and `(step, region)` random access.
//!
//! Simulation codes emit data *as a stream of timesteps*, and
//! frame-to-frame redundancy dominates CFD/climate output — yet a plain
//! [`crate::codec::Codec`] call compresses each timestep independently,
//! discarding exactly the temporal correlation the paper says reduction
//! must exploit. This module adds the missing workload on top of the
//! existing engine and archive formats:
//!
//! * **v4 `TSTR` container** (framing in [`crate::compressor::format`]):
//!   a self-describing header, then one self-delimiting record per step
//!   (`KSTP`/`RSTP`, each embedding a complete v1/v3 archive), then a
//!   [`TimelineIndex`] (`TIDX`) + footer written on `finish`. Unsealed
//!   streams (crash, still-growing producer) recover by scanning.
//! * **Keyframe/residual coding** (the `residual` submodule): every
//!   K-th step is a keyframe compressed with any existing codec;
//!   intermediate steps code `frame - prev_reconstruction`, so the
//!   typed [`crate::codec::ErrorBound`] holds on every *absolute* frame
//!   with no accumulation along the chain
//!   ([`crate::codec::ErrorBound::for_residual`] handles the
//!   range-relative variants). With K = 1 a stream degenerates to
//!   independent per-step archives, byte-identical to `Codec::compress`.
//! * **[`StreamWriter`]** — incremental ingest: `create`, `append` (or
//!   GOP-parallel [`StreamWriter::append_frames`] on the shared
//!   [`crate::engine::Executor`]), `finish`; `reopen` continues a stream
//!   across process lifetimes.
//! * **[`StreamReader`]** — `(step, region)` random access decoding
//!   only the chain `keyframe..=step`, and within each chain archive
//!   only the blocks the region intersects (its `BIDX`); plus an
//!   in-order playback iterator that decodes each step once.
//!   [`StreamReader::region_cost`] accounts the bytes a region decode
//!   touches.
//!
//! The keyframe interval K trades compression for access latency:
//! larger K amortizes keyframe cost over more (much smaller) residuals
//! but lengthens the chain a random access must decode. The
//! `stream_throughput` bench sweeps K and reports both sides.
//!
//! ```ignore
//! use attn_reduce::stream::{StreamReader, StreamWriter};
//!
//! let mut w = StreamWriter::create("run.tstr", codec.id(), frame_cfg, bound, 8)?;
//! for frame in frames {
//!     w.append(&*codec, &frame)?;
//! }
//! w.finish()?;
//!
//! let r = StreamReader::open("run.tstr")?;
//! let codec = r.build_codec(&mut builder)?;        // self-describing
//! let t42 = r.frame(&*codec, 42)?;                 // keyframe + residuals
//! let roi = r.extract(&*codec, 42, &region)?;      // only intersecting blocks
//! ```

mod reader;
mod residual;
mod timeline;
mod writer;

pub use reader::{FrameIter, RegionCost, SharedReader, StreamReader, StreamStats};
pub use residual::{add_residual, encode_chain, residual_of, EncodedStep};
pub use timeline::{StepEntry, TimelineIndex};
pub use writer::{StepStats, StreamSummary, StreamWriter};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{Codec, ErrorBound, Sz3Codec};
    use crate::config::{DatasetConfig, DatasetKind, Normalization};
    use crate::data::{timeseries, Region};

    fn frame_cfg() -> DatasetConfig {
        DatasetConfig {
            kind: DatasetKind::E3sm,
            dims: vec![24, 32],
            ae_block: vec![8, 8],
            k: 2,
            hyper_axis: 0,
            gae_block: vec![4, 4],
            normalization: Normalization::ZScore,
            seed: 9,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("attn_reduce_stream_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn write_read_roundtrip_with_random_access() {
        let cfg = frame_cfg();
        let codec = Sz3Codec::new(cfg.clone());
        let frames = timeseries::generate_frames(&cfg.dims, 5, 0, 7);
        let bound = ErrorBound::Nrmse(1e-3);
        let path = tmp("roundtrip.tstr");
        let mut w = StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 3).unwrap();
        for f in &frames {
            w.append(&codec, f).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.steps, 7);
        assert_eq!(summary.keyframes, 3); // steps 0, 3, 6

        let r = StreamReader::open(&path).unwrap();
        assert!(r.is_finished());
        assert_eq!(r.n_steps(), 7);
        assert_eq!(r.keyframe_interval(), 3);
        assert_eq!(r.codec_id(), "sz3");
        // every random-access frame meets the bound on the absolute frame
        for (t, orig) in frames.iter().enumerate() {
            let recon = r.frame(&codec, t).unwrap();
            assert!(
                ErrorBound::Nrmse(1e-3 * 1.0001).satisfied_by(orig, &recon, &cfg),
                "step {t} violates the bound"
            );
        }
        // playback iterator agrees with random access bit-for-bit
        for (t, f) in r.frames(&codec).enumerate() {
            assert_eq!(f.unwrap().data(), r.frame(&codec, t).unwrap().data(), "step {t}");
        }
        // region extraction is bit-identical to cropping the full frame
        let region = Region::parse("4:20,8:24").unwrap();
        for t in [0, 2, 4, 6] {
            let part = r.extract(&codec, t, &region).unwrap();
            let crop = region.crop(&r.frame(&codec, t).unwrap()).unwrap();
            assert_eq!(part.data(), crop.data(), "step {t} region mismatch");
        }
    }

    #[test]
    fn region_decode_touches_only_intersecting_chain_blocks() {
        let cfg = frame_cfg();
        let codec = Sz3Codec::new(cfg.clone());
        let frames = timeseries::generate_frames(&cfg.dims, 5, 0, 6);
        let path = tmp("cost.tstr");
        let mut w =
            StreamWriter::create(&path, codec.id(), cfg.clone(), ErrorBound::Nrmse(1e-3), 4)
                .unwrap();
        w.append_frames(&codec, &frames).unwrap();
        w.finish().unwrap();
        let r = StreamReader::open(&path).unwrap();
        // one 8x8 tile of a 3x4 tiling
        let region = Region::parse("0:8,0:8").unwrap();
        let cost = r.region_cost(5, &region).unwrap();
        assert_eq!(cost.steps, 2); // keyframe 4 + residual 5
        assert_eq!(cost.blocks_total, 2 * 12);
        assert_eq!(cost.blocks_touched, 2 * 1);
        assert!(cost.bytes_touched < cost.bytes_total);
        // the exact byte accounting: sum of the intersecting entries of
        // each chain archive's BIDX, nothing more
        let mut want = 0usize;
        for s in 4..=5 {
            let idx = r.step_archive(s).unwrap().block_index().unwrap().unwrap();
            let ids = crate::data::region_tile_ids(&cfg.dims, &idx.tile, &region);
            assert_eq!(ids, vec![0]);
            want += idx.bytes_for(&ids);
        }
        assert_eq!(cost.bytes_touched, want);
        // a full-frame region touches everything in the chain
        let full = Region::full(&cfg.dims);
        let all = r.region_cost(5, &full).unwrap();
        assert_eq!(all.bytes_touched, all.bytes_total);
    }

    #[test]
    fn bulk_append_is_byte_identical_to_sequential() {
        let cfg = frame_cfg();
        let codec = Sz3Codec::new(cfg.clone());
        let frames = timeseries::generate_frames(&cfg.dims, 5, 0, 9);
        let bound = ErrorBound::PointwiseAbs(1e-3 * 8.0);
        let (pa, pb) = (tmp("seq.tstr"), tmp("bulk.tstr"));
        let mut w = StreamWriter::create(&pa, codec.id(), cfg.clone(), bound, 4).unwrap();
        for f in &frames {
            w.append(&codec, f).unwrap();
        }
        w.finish().unwrap();
        let mut w = StreamWriter::create(&pb, codec.id(), cfg.clone(), bound, 4).unwrap();
        w.append_frames(&codec, &frames).unwrap();
        w.finish().unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    }

    #[test]
    fn reopen_continues_the_stream_and_its_chains() {
        let cfg = frame_cfg();
        let codec = Sz3Codec::new(cfg.clone());
        let frames = timeseries::generate_frames(&cfg.dims, 5, 0, 8);
        let bound = ErrorBound::Nrmse(1e-3);
        // one-shot reference
        let pa = tmp("oneshot.tstr");
        let mut w = StreamWriter::create(&pa, codec.id(), cfg.clone(), bound, 3).unwrap();
        w.append_frames(&codec, &frames).unwrap();
        w.finish().unwrap();
        // split mid-GOP: 5 steps (ends inside the second GOP), then reopen
        let pb = tmp("split.tstr");
        let mut w = StreamWriter::create(&pb, codec.id(), cfg.clone(), bound, 3).unwrap();
        w.append_frames(&codec, &frames[..5]).unwrap();
        w.finish().unwrap();
        let mut w = StreamWriter::reopen(&pb, &codec).unwrap();
        assert_eq!(w.next_step(), 5);
        for f in &frames[5..] {
            w.append(&codec, f).unwrap();
        }
        w.finish().unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        // reopening with the wrong codec is a typed error
        let zfp = crate::codec::ZfpCodec::new(cfg.clone());
        let err = StreamWriter::reopen(&pb, &zfp).unwrap_err().to_string();
        assert!(err.contains("codec"), "{err}");
    }

    #[test]
    fn unsealed_streams_recover_by_scanning() {
        let cfg = frame_cfg();
        let codec = Sz3Codec::new(cfg.clone());
        let frames = timeseries::generate_frames(&cfg.dims, 5, 0, 4);
        let path = tmp("unsealed.tstr");
        let mut w =
            StreamWriter::create(&path, codec.id(), cfg.clone(), ErrorBound::Nrmse(1e-3), 2)
                .unwrap();
        for f in &frames {
            w.append(&codec, f).unwrap();
        }
        drop(w); // never finished — no TIDX, no footer
        let r = StreamReader::open(&path).unwrap();
        assert!(!r.is_finished());
        assert_eq!(r.n_steps(), 4);
        let recon = r.frame(&codec, 3).unwrap();
        assert!(ErrorBound::Nrmse(1e-3 * 1.0001).satisfied_by(&frames[3], &recon, &cfg));
        // reopen after the crash and seal it
        let mut w = StreamWriter::reopen(&path, &codec).unwrap();
        assert_eq!(w.next_step(), 4);
        w.finish().unwrap();
        assert!(StreamReader::open(&path).unwrap().is_finished());
    }

    #[test]
    fn writer_misuse_is_rejected() {
        let cfg = frame_cfg();
        let codec = Sz3Codec::new(cfg.clone());
        let path = tmp("misuse.tstr");
        assert!(
            StreamWriter::create(&path, "sz3", cfg.clone(), ErrorBound::None, 0).is_err(),
            "keyint 0"
        );
        let mut w =
            StreamWriter::create(&path, "sz3", cfg.clone(), ErrorBound::Nrmse(1e-3), 2).unwrap();
        // wrong codec id
        let zfp = crate::codec::ZfpCodec::new(cfg.clone());
        let frame = timeseries::frame_at(&cfg.dims, 5, 0);
        assert!(w.append(&zfp, &frame).is_err());
        // wrong frame shape
        let bad = crate::tensor::Tensor::zeros(vec![3, 3]);
        assert!(w.append(&codec, &bad).is_err());
        assert_eq!(w.next_step(), 0, "failed appends must not advance the stream");
    }
}
