//! [`StreamWriter`] — append-only producer of v4 temporal streams.
//!
//! `create` writes the `TSTR` header; every `append` adds one step
//! record (`KSTP` keyframe / `RSTP` residual, chosen by `step % K`);
//! `finish` seals the stream with the `TIDX` timeline record and the
//! 12-byte footer. A stream that was never finished (crash, or a
//! producer still running) is readable too — [`super::StreamReader`]
//! recovers the timeline by scanning complete records — and `reopen`
//! continues appending to either kind, reconstructing the chain state
//! from the existing steps, so simulation output can be ingested
//! incrementally across process lifetimes.
//!
//! [`StreamWriter::append_frames`] is the bulk path: whole GOPs
//! (keyframe + following residuals) are independent, so they are
//! scheduled across the [`Executor`] worker pool while the records still
//! land on disk in step order — output is byte-identical to sequential
//! `append` calls at every thread count.

use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::codec::{Codec, ErrorBound};
use crate::compressor::format::{
    stream_header_bytes, stream_record_bytes, stream_record_bytes_checked,
    STREAM_END_MAGIC, STREAM_KEY_TAG, STREAM_RES_TAG, STREAM_TIDX_TAG, STREAM_XSUM_TAG,
    XSUM_HEADER_KEY,
};
use crate::config::DatasetConfig;
use crate::engine::Executor;
use crate::tensor::Tensor;
use crate::util::{crc32c, durable, json};
use crate::Result;
use anyhow::{ensure, Context};

/// Failpoint covering every byte this writer puts on disk (header,
/// records, index, footer) — `ATTN_FAILPOINT="stream.write=after:N"`
/// tears the stream N bytes in; `after:N:exit:C` kills the process
/// there, which is how the crash-recovery suite simulates kill -9
/// mid-append.
pub const FP_STREAM_WRITE: &str = "stream.write";

use super::residual::{encode_chain, EncodedStep};
use super::timeline::{StepEntry, TimelineIndex};
use super::StreamReader;

/// What one `append` did (sizes in bytes).
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: usize,
    pub keyframe: bool,
    /// On-disk record bytes (framing + embedded archive).
    pub record_bytes: usize,
    /// CR-payload bytes of the step archive (paper accounting).
    pub payload_bytes: usize,
}

/// What a sealed stream holds (returned by [`StreamWriter::finish`]).
#[derive(Debug, Clone, Copy)]
pub struct StreamSummary {
    pub steps: usize,
    pub keyframes: usize,
    /// Total file size including header, framing, index, and footer.
    pub file_bytes: u64,
    /// Summed CR-payload bytes across all step archives.
    pub payload_bytes: usize,
}

/// Append-only writer over one v4 stream file.
pub struct StreamWriter {
    file: std::fs::File,
    path: PathBuf,
    dataset: DatasetConfig,
    bound: ErrorBound,
    codec_id: String,
    keyint: usize,
    entries: Vec<StepEntry>,
    payload_bytes: usize,
    /// Reconstruction of the last appended step (chain state); `None`
    /// exactly when the next step is a keyframe.
    prev_recon: Option<Tensor>,
    offset: u64,
    /// Checked framing: records carry a trailing CRC32C and the header
    /// is covered by an `XSUM` record. True for every stream `create`
    /// writes; reopened legacy streams keep their original framing so
    /// one file never mixes record layouts.
    checked: bool,
}

impl StreamWriter {
    /// Create a new stream at `path` (parent dirs are created). The
    /// header records `codec_id`, the per-frame `dataset` geometry, the
    /// stream-wide `bound`, and the keyframe interval `keyint` — the
    /// stream is self-describing like every archive.
    pub fn create(
        path: impl AsRef<Path>,
        codec_id: &str,
        dataset: DatasetConfig,
        bound: ErrorBound,
        keyint: usize,
    ) -> Result<Self> {
        ensure!(keyint >= 1, "keyframe interval must be at least 1");
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let header = json::obj(vec![
            ("codec", json::s(codec_id)),
            ("bound", bound.to_json()),
            ("dataset", dataset.to_json()),
            ("keyint", json::num(keyint as f64)),
            (XSUM_HEADER_KEY, json::num(1.0)),
        ]);
        let bytes = stream_header_bytes(&header);
        let mut file = std::fs::File::create(&path)
            .with_context(|| format!("creating stream {}", path.display()))?;
        durable::write_all_hooked(&mut file, FP_STREAM_WRITE, &bytes)?;
        // the XSUM record pins the header bytes under a CRC; step records
        // follow it, each carrying its own trailing CRC
        let xsum =
            stream_record_bytes_checked(STREAM_XSUM_TAG, &crc32c::crc32c(&bytes).to_le_bytes());
        durable::write_all_hooked(&mut file, FP_STREAM_WRITE, &xsum)?;
        file.sync_all()
            .with_context(|| format!("fsyncing stream {}", path.display()))?;
        Ok(Self {
            file,
            path,
            dataset,
            bound,
            codec_id: codec_id.to_string(),
            keyint,
            entries: Vec::new(),
            payload_bytes: 0,
            prev_recon: None,
            offset: (bytes.len() + xsum.len()) as u64,
            checked: true,
        })
    }

    /// Reopen an existing stream for further appends. Works on both
    /// sealed streams (the index/footer are truncated away and rewritten
    /// by the next `finish`) and unsealed ones (the timeline is
    /// recovered by scanning). `codec` must match the stream's recorded
    /// codec; it is used to reconstruct the chain state when the next
    /// step continues a GOP.
    pub fn reopen(path: impl AsRef<Path>, codec: &dyn Codec) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let reader = StreamReader::open(&path)?;
        Self::reopen_from(path, reader, codec)
    }

    /// [`Self::reopen`] when the caller has already opened a
    /// [`StreamReader`] on `path` (avoids reading and parsing the file a
    /// second time — the CLI `stream append` path, which first consults
    /// the header for the codec).
    pub fn reopen_from(
        path: impl AsRef<Path>,
        reader: StreamReader,
        codec: &dyn Codec,
    ) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        ensure!(
            codec.id() == reader.codec_id(),
            "stream {} was written with codec {:?}, reopened with {:?}",
            path.display(),
            reader.codec_id(),
            codec.id()
        );
        let n = reader.n_steps();
        let keyint = reader.keyframe_interval();
        // chain state: only needed when step n continues the last GOP
        let prev_recon = if n > 0 && n % keyint != 0 {
            Some(reader.frame(codec, n - 1)?)
        } else {
            None
        };
        let entries = reader.timeline().entries.clone();
        let payload_bytes = (0..n)
            .map(|s| Ok(reader.step_archive(s)?.cr_payload_bytes()))
            .sum::<Result<usize>>()?;
        // truncate to the end of the last complete step record — drops
        // any index/footer (rewritten on finish) and any torn record;
        // checked records end 4 bytes past the payload (trailing CRC)
        let checked = reader.is_checksummed();
        let crc_len = if checked { 4 } else { 0 };
        let end = entries
            .last()
            .map(|e| e.offset + e.len + crc_len)
            .unwrap_or_else(|| reader.records_start() as u64);
        let dataset = reader.dataset().clone();
        let bound = reader.bound();
        let codec_id = reader.codec_id().to_string();
        drop(reader);
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("reopening stream {}", path.display()))?;
        file.set_len(end)?;
        file.seek(SeekFrom::End(0))?;
        Ok(Self {
            file,
            path,
            dataset,
            bound,
            codec_id,
            keyint,
            entries,
            payload_bytes,
            prev_recon,
            offset: end,
            checked,
        })
    }

    /// The absolute step id the next `append` will write.
    pub fn next_step(&self) -> usize {
        self.entries.len()
    }

    pub fn keyframe_interval(&self) -> usize {
        self.keyint
    }

    pub fn dataset(&self) -> &DatasetConfig {
        &self.dataset
    }

    pub fn bound(&self) -> ErrorBound {
        self.bound
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn check_codec_and_frame(&self, codec: &dyn Codec, frame: &Tensor) -> Result<()> {
        ensure!(
            codec.id() == self.codec_id,
            "stream records codec {:?}, append called with {:?}",
            self.codec_id,
            codec.id()
        );
        ensure!(
            frame.shape() == &self.dataset.dims[..],
            "frame shape {:?} != stream frame dims {:?}",
            frame.shape(),
            self.dataset.dims
        );
        Ok(())
    }

    fn write_encoded(&mut self, steps: Vec<EncodedStep>) -> Result<Vec<StepStats>> {
        let mut out = Vec::with_capacity(steps.len());
        for s in steps {
            let tag = if s.keyframe { STREAM_KEY_TAG } else { STREAM_RES_TAG };
            let record = if self.checked {
                stream_record_bytes_checked(tag, &s.bytes)
            } else {
                stream_record_bytes(tag, &s.bytes)
            };
            durable::write_all_hooked(&mut self.file, FP_STREAM_WRITE, &record)?;
            self.entries.push(StepEntry {
                keyframe: s.keyframe,
                offset: self.offset + 12,
                len: s.bytes.len() as u64,
            });
            out.push(StepStats {
                step: self.entries.len() - 1,
                keyframe: s.keyframe,
                record_bytes: record.len(),
                payload_bytes: s.payload_bytes,
            });
            self.payload_bytes += s.payload_bytes;
            self.offset += record.len() as u64;
        }
        Ok(out)
    }

    /// Append one timestep. Every `keyint`-th step (by absolute id) is a
    /// keyframe; the rest code temporal residuals against the running
    /// reconstruction, so the stream bound holds on every absolute frame.
    pub fn append(&mut self, codec: &dyn Codec, frame: &Tensor) -> Result<StepStats> {
        self.check_codec_and_frame(codec, frame)?;
        let step = self.next_step();
        let prev = if step % self.keyint == 0 { None } else { self.prev_recon.as_ref() };
        let (steps, last) = encode_chain(
            codec,
            std::slice::from_ref(frame),
            step,
            self.keyint,
            &self.bound,
            prev,
        )?;
        self.prev_recon = last;
        Ok(self.write_encoded(steps)?.remove(0))
    }

    /// Bulk append with GOP-level parallelism: complete GOPs are
    /// independent chains, so they compress concurrently on the shared
    /// [`Executor`] pool (each step's blocks additionally fan out inside
    /// its GOP job). Records land in step order — the file is
    /// byte-identical to sequential `append`s at every thread count.
    pub fn append_frames<C: Codec + Sync>(
        &mut self,
        codec: &C,
        frames: &[Tensor],
    ) -> Result<Vec<StepStats>> {
        for f in frames {
            self.check_codec_and_frame(codec, f)?;
        }
        let start = self.next_step();
        // finish the in-progress GOP sequentially (it needs prev_recon)
        let head_len = (self.keyint - start % self.keyint) % self.keyint;
        let head_len = head_len.min(frames.len());
        let mut stats = Vec::with_capacity(frames.len());
        if head_len > 0 {
            let _span = crate::obs::stages::STREAM_APPEND_GOP.span();
            let (steps, last) = encode_chain(
                codec,
                &frames[..head_len],
                start,
                self.keyint,
                &self.bound,
                self.prev_recon.as_ref(),
            )?;
            self.prev_recon = last;
            stats.extend(self.write_encoded(steps)?);
        }
        let rest = &frames[head_len..];
        if rest.is_empty() {
            return Ok(stats);
        }
        // whole GOPs from here: fan them out across the pool
        let gops: Vec<&[Tensor]> = rest.chunks(self.keyint).collect();
        let gop_start = start + head_len;
        let keyint = self.keyint;
        let bound = self.bound;
        let encoded = Executor::global().try_par_map(gops.len(), |g| {
            let _span = crate::obs::stages::STREAM_APPEND_GOP.span();
            encode_chain(codec, gops[g], gop_start + g * keyint, keyint, &bound, None)
        })?;
        for (steps, last) in encoded {
            self.prev_recon = last;
            stats.extend(self.write_encoded(steps)?);
        }
        Ok(stats)
    }

    /// Seal the stream: write the `TIDX` timeline record and the footer
    /// locating it. The file stays valid for `reopen` afterwards.
    pub fn finish(mut self) -> Result<StreamSummary> {
        let index = TimelineIndex {
            keyframe_interval: self.keyint as u32,
            entries: self.entries.clone(),
        };
        let tidx_offset = self.offset;
        let record = if self.checked {
            stream_record_bytes_checked(STREAM_TIDX_TAG, &index.to_bytes())
        } else {
            stream_record_bytes(STREAM_TIDX_TAG, &index.to_bytes())
        };
        durable::write_all_hooked(&mut self.file, FP_STREAM_WRITE, &record)?;
        let mut footer = Vec::with_capacity(12);
        footer.extend_from_slice(&tidx_offset.to_le_bytes());
        footer.extend_from_slice(STREAM_END_MAGIC);
        durable::write_all_hooked(&mut self.file, FP_STREAM_WRITE, &footer)?;
        self.file.flush()?;
        self.file
            .sync_all()
            .with_context(|| format!("fsyncing stream {}", self.path.display()))?;
        let file_bytes = self.offset + record.len() as u64 + 12;
        Ok(StreamSummary {
            steps: self.entries.len(),
            keyframes: self.entries.iter().filter(|e| e.keyframe).count(),
            file_bytes,
            payload_bytes: self.payload_bytes,
        })
    }

}
