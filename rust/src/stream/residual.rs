//! The temporal residual coding stage.
//!
//! Every K-th step is a **keyframe**: the absolute frame compressed with
//! the stream's codec under the stream's bound. Intermediate steps are
//! **residuals**: `frame_t - recon_{t-1}`, where `recon_{t-1}` is the
//! previous frame's *reconstruction* (not its raw values) — so the error
//! of the absolute frame at every step equals the error of that one
//! step's coding, and the typed [`ErrorBound`] holds on every frame of a
//! residual chain with no accumulation ([`ErrorBound::for_residual`]
//! translates range-relative bounds into frame units).
//!
//! A keyframe plus its residuals form a **GOP** (group of pictures, in
//! video terms). GOPs share no state, which is what
//! [`crate::stream::StreamWriter::append_frames`] exploits to schedule
//! whole GOPs across the [`crate::engine::Executor`] worker pool.
//!
//! Residual tiles are heavily zero-peaked (most of a frame changes by
//! less than the bound between steps), so their per-tile entropy streams
//! ride the symbol container's zero-run / constant modes
//! ([`crate::coder::compress_symbols`]) whenever trial sampling says
//! they beat plain Huffman+LZSS — an all-zero residual tile costs a few
//! bytes instead of a full Huffman table. Keyframes keep selecting plain
//! for their dense code streams, and the choice is per tile and
//! data-deterministic, so streams stay byte-identical at every thread
//! count.

use crate::codec::{Codec, ErrorBound};
use crate::tensor::Tensor;
use crate::Result;
use anyhow::ensure;

/// `frame - prev_recon`, elementwise.
pub fn residual_of(frame: &Tensor, prev_recon: &Tensor) -> Tensor {
    debug_assert_eq!(frame.shape(), prev_recon.shape());
    let data = frame
        .data()
        .iter()
        .zip(prev_recon.data())
        .map(|(&f, &p)| f - p)
        .collect();
    Tensor::new(frame.shape().to_vec(), data)
}

/// `prev_recon + residual_recon`, elementwise — the absolute frame a
/// residual decode reconstructs. Addition order is fixed (prev first),
/// so chain decodes are bit-identical however they are assembled.
pub fn add_residual(prev_recon: &Tensor, residual_recon: &Tensor) -> Tensor {
    debug_assert_eq!(prev_recon.shape(), residual_recon.shape());
    let data = prev_recon
        .data()
        .iter()
        .zip(residual_recon.data())
        .map(|(&p, &r)| p + r)
        .collect();
    Tensor::new(prev_recon.shape().to_vec(), data)
}

/// One encoded step of a GOP: the serialized step archive plus what the
/// timeline needs to index it.
pub struct EncodedStep {
    pub keyframe: bool,
    pub bytes: Vec<u8>,
    /// CR-payload bytes of the step archive (paper accounting).
    pub payload_bytes: usize,
}

/// Encode `frames` as one chain starting at absolute step `start`:
/// steps where `step % keyint == 0` restart the chain as keyframes,
/// other steps code residuals against the running reconstruction.
/// `prev_recon` carries the chain state into a non-keyframe start (the
/// reopen-mid-GOP case) and must be `Some` iff `start % keyint != 0`.
/// Returns the encoded steps plus the final reconstruction (the chain
/// state for whatever is appended next).
pub fn encode_chain(
    codec: &dyn Codec,
    frames: &[Tensor],
    start: usize,
    keyint: usize,
    bound: &ErrorBound,
    prev_recon: Option<&Tensor>,
) -> Result<(Vec<EncodedStep>, Option<Tensor>)> {
    ensure!(keyint >= 1, "keyframe interval must be at least 1");
    ensure!(
        (start % keyint == 0) != prev_recon.is_some(),
        "chain state mismatch: step {start} with keyint {keyint} \
         {} a previous reconstruction",
        if prev_recon.is_some() { "must not carry" } else { "needs" }
    );
    let mut out = Vec::with_capacity(frames.len());
    let mut prev = prev_recon.cloned();
    for (i, frame) in frames.iter().enumerate() {
        let step = start + i;
        let keyframe = step % keyint == 0;
        let (archive, recon) = if keyframe {
            codec.compress_with_recon(frame, bound)?
        } else {
            let base = prev.as_ref().expect("residual step has a previous recon");
            let residual = residual_of(frame, base);
            let (archive, res_recon) =
                codec.compress_residual(&residual, bound, frame.range() as f64)?;
            (archive, add_residual(base, &res_recon))
        };
        out.push(EncodedStep {
            keyframe,
            payload_bytes: archive.cr_payload_bytes(),
            bytes: archive.to_bytes(),
        });
        prev = Some(recon);
    }
    Ok((out, prev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Sz3Codec;
    use crate::config::{dataset_preset, DatasetKind, Scale};
    use crate::data;

    #[test]
    fn residual_ops_are_exact_inverses() {
        let a = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::new(vec![2, 3], vec![0.5, 2.5, 2.0, 4.0, 7.0, -1.0]);
        let r = residual_of(&a, &b);
        assert_eq!(r.data(), &[0.5, -0.5, 1.0, 0.0, -2.0, 7.0]);
        let back = add_residual(&b, &r);
        assert_eq!(back.data(), a.data());
    }

    #[test]
    fn chain_bounds_hold_on_absolute_frames() {
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let codec = Sz3Codec::new(cfg.clone());
        let f0 = data::generate(&cfg);
        // a smoothly-shifted second and third frame
        let mut f1 = f0.clone();
        for v in f1.data_mut() {
            *v += 3.0;
        }
        let mut f2 = f1.clone();
        for v in f2.data_mut() {
            *v *= 1.0001;
        }
        let bound = ErrorBound::Nrmse(1e-3);
        let frames = [f0.clone(), f1.clone(), f2.clone()];
        let (steps, last) = encode_chain(&codec, &frames, 0, 3, &bound, None).unwrap();
        assert_eq!(steps.len(), 3);
        assert!(steps[0].keyframe && !steps[1].keyframe && !steps[2].keyframe);
        // replay the chain by decoding the emitted archives
        let mut prev: Option<Tensor> = None;
        for (frame, step) in frames.iter().zip(&steps) {
            let archive = crate::compressor::Archive::from_bytes(&step.bytes).unwrap();
            let dec = codec.decompress(&archive).unwrap();
            let recon = match &prev {
                None => dec,
                Some(p) => add_residual(p, &dec),
            };
            assert!(
                ErrorBound::Nrmse(1e-3 * 1.0001).satisfied_by(frame, &recon, &cfg),
                "bound violated on a chain frame"
            );
            prev = Some(recon);
        }
        // the writer-side running recon equals the replayed one
        assert_eq!(last.unwrap().data(), prev.unwrap().data());
    }

    #[test]
    fn chain_state_misuse_is_an_error() {
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let codec = Sz3Codec::new(cfg.clone());
        let f = data::generate(&cfg);
        let frames = [f.clone()];
        // keyframe start must not carry state
        assert!(encode_chain(&codec, &frames, 0, 2, &ErrorBound::None, Some(&f)).is_err());
        // mid-GOP start needs state
        assert!(encode_chain(&codec, &frames, 1, 2, &ErrorBound::None, None).is_err());
        assert!(encode_chain(&codec, &frames, 0, 0, &ErrorBound::None, None).is_err());
    }
}
