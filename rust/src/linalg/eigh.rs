//! Dense symmetric eigensolver: Householder tridiagonalization (`tred2`)
//! followed by implicit-shift QL with eigenvector accumulation (`tql2`).
//!
//! Classic EISPACK algorithms (Numerical Recipes §11.2–11.3), O(n³),
//! numerically robust for the residual covariance matrices the GAE stage
//! produces (n = GAE block length: 80 for S3D, 256 for E3SM, 1521 for XGC).

use crate::Result;
use anyhow::bail;

/// Eigen-decomposition of a symmetric matrix (row-major `a`, `n x n`).
///
/// Returns `(eigenvalues, eigenvectors)` sorted by **descending**
/// eigenvalue; eigenvectors are the *columns* of the returned row-major
/// matrix `v` (i.e. `v[i*n + j]` is component `i` of eigenvector `j`),
/// matching the paper's basis-matrix convention `U`.
pub fn eigh_symmetric(a: &[f64], n: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    if a.len() != n * n {
        bail!("eigh: matrix len {} != n^2 ({n})", a.len());
    }
    if n == 0 {
        return Ok((vec![], vec![]));
    }
    // verify symmetry (cheap guard against caller bugs)
    for i in 0..n {
        for j in (i + 1)..n {
            let d = (a[i * n + j] - a[j * n + i]).abs();
            let scale = a[i * n + j].abs().max(a[j * n + i].abs()).max(1.0);
            if d > 1e-8 * scale {
                bail!("eigh: matrix not symmetric at ({i},{j}): {d}");
            }
        }
    }

    let mut v = a.to_vec(); // will become the eigenvector matrix
    let mut d = vec![0.0; n]; // diagonal
    let mut e = vec![0.0; n]; // off-diagonal

    tred2(&mut v, n, &mut d, &mut e);
    tql2(&mut v, n, &mut d, &mut e)?;

    // sort descending by eigenvalue, permuting columns of v
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let mut dv = vec![0.0; n];
    let mut vv = vec![0.0; n * n];
    for (newj, &oldj) in order.iter().enumerate() {
        dv[newj] = d[oldj];
        for i in 0..n {
            vv[i * n + newj] = v[i * n + oldj];
        }
    }
    Ok((dv, vv))
}

/// Householder reduction to tridiagonal form (Numerical Recipes `tred2`).
fn tred2(v: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += v[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = v[i * n + l];
            } else {
                for k in 0..=l {
                    v[i * n + k] /= scale;
                    h += v[i * n + k] * v[i * n + k];
                }
                let mut f = v[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                v[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    v[j * n + i] = v[i * n + j] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += v[j * n + k] * v[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += v[k * n + j] * v[i * n + k];
                    }
                    e[j] = g / h;
                    f += e[j] * v[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = v[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        v[j * n + k] -= f * e[k] + g * v[i * n + k];
                    }
                }
            }
        } else {
            e[i] = v[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += v[i * n + k] * v[k * n + j];
                }
                for k in 0..i {
                    v[k * n + j] -= g * v[k * n + i];
                }
            }
        }
        d[i] = v[i * n + i];
        v[i * n + i] = 1.0;
        for j in 0..i {
            v[j * n + i] = 0.0;
            v[i * n + j] = 0.0;
        }
    }
}

/// Implicit-shift QL with eigenvector accumulation (`tql2`).
fn tql2(v: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) -> Result<()> {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small off-diagonal
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                bail!("tql2: no convergence after 50 iterations");
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sgn = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sgn);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate eigenvectors
                for k in 0..n {
                    f = v[k * n + i + 1];
                    v[k * n + i + 1] = s * v[k * n + i] + c * f;
                    v[k * n + i] = c * v[k * n + i] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_decomposition(a: &[f64], n: usize, tol: f64) {
        let (vals, vecs) = eigh_symmetric(a, n).unwrap();
        // descending order
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "not sorted: {vals:?}");
        }
        // A v_j = lambda_j v_j
        for j in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * vecs[k * n + j];
                }
                let lv = vals[j] * vecs[i * n + j];
                assert!(
                    (av - lv).abs() < tol,
                    "residual at ({i},{j}): {av} vs {lv}"
                );
            }
        }
        // orthonormal columns
        for j1 in 0..n {
            for j2 in 0..n {
                let mut dp = 0.0;
                for i in 0..n {
                    dp += vecs[i * n + j1] * vecs[i * n + j2];
                }
                let want = if j1 == j2 { 1.0 } else { 0.0 };
                assert!((dp - want).abs() < tol, "orthonormality ({j1},{j2}): {dp}");
            }
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let (vals, _) = eigh_symmetric(&a, 3).unwrap();
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 1.0).abs() < 1e-12);
        check_decomposition(&a, 3, 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3 and 1
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let (vals, _) = eigh_symmetric(&a, 2).unwrap();
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_symmetric_psd_sizes() {
        let mut rng = Rng::new(5);
        for &n in &[1usize, 2, 3, 5, 16, 40] {
            // A = B Bᵀ / n — symmetric PSD
            let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += b[i * n + k] * b[j * n + k];
                    }
                    a[i * n + j] = acc / n as f64;
                }
            }
            check_decomposition(&a, n, 1e-8);
            let (vals, _) = eigh_symmetric(&a, n).unwrap();
            assert!(vals.iter().all(|&l| l > -1e-9), "PSD: {vals:?}");
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(11);
        let n = 24;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let x = rng.normal();
                a[i * n + j] = x;
                a[j * n + i] = x;
            }
        }
        let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
        let (vals, _) = eigh_symmetric(&a, n).unwrap();
        let sum: f64 = vals.iter().sum();
        assert!((trace - sum).abs() < 1e-8 * trace.abs().max(1.0));
    }

    #[test]
    fn rejects_asymmetric() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        assert!(eigh_symmetric(&a, 2).is_err());
    }

    #[test]
    fn rejects_bad_len() {
        assert!(eigh_symmetric(&[1.0; 5], 2).is_err());
    }
}
