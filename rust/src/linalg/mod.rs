//! Linear-algebra substrate for the GAE stage (paper §II-D).
//!
//! The error-bound guarantee needs a PCA basis over the residual blocks:
//! covariance accumulation, a dense symmetric eigensolver, and
//! project/reconstruct helpers. Implemented from scratch (no LAPACK):
//! Householder tridiagonalization + implicit-shift QL — the classic
//! EISPACK `tred2`/`tql2` pair — in f64 for stability.

mod eigh;
mod pca;

pub use eigh::eigh_symmetric;
pub use pca::{covariance, Pca};

/// y = A x for row-major `a` of shape `[m, n]`.
pub fn matvec(a: &[f64], m: usize, n: usize, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * x[j];
        }
        y[i] = acc;
    }
}

/// y = Aᵀ x for row-major `a` of shape `[m, n]` (no transpose copy).
pub fn matvec_t(a: &[f64], m: usize, n: usize, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let xi = x[i];
        for j in 0..n {
            y[j] += row[j] * xi;
        }
    }
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// ℓ2 norm of an f32 slice, accumulated in f64 (the GAE bound check).
pub fn norm2_f32(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let x = vec![3.0, -2.0];
        let mut y = vec![0.0; 2];
        matvec(&a, 2, 2, &x, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn matvec_t_is_transpose() {
        // A = [[1,2,3],[4,5,6]]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![1.0, 1.0];
        let mut y = vec![0.0; 3];
        matvec_t(&a, 2, 3, &x, &mut y);
        assert_eq!(y, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn norm2_matches_manual() {
        assert!((norm2_f32(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
