//! PCA over residual blocks (paper §II-D, Eq. 9).
//!
//! The GAE stage runs PCA on the residuals Ω − Ω^R of the *entire*
//! dataset: each flattened GAE block is one instance; the basis matrix
//! `U` (eigenvectors of the residual covariance, descending eigenvalue) is
//! shared by all blocks and stored once in the archive.
//!
//! The paper does not center the residuals before projection — Eq. 9 is
//! `c = Uᵀ(x − x^R)` with exact recovery `Uc` — so this PCA is
//! *uncentered* (a.k.a. the autocorrelation basis): covariance is
//! `Σ xxᵀ / N` without mean subtraction. That keeps the per-block
//! correction self-contained (no mean vector needed at decode).

use crate::util::parallel;
use crate::Result;

use super::eigh_symmetric;

/// Maximum number of partial matrices the parallel covariance
/// accumulation materializes. Bounds peak memory at `16 · n² · 8` bytes
/// (the old thread-derived partition's worst case) while keeping chunk
/// boundaries a function of the *row count only* — never the thread
/// count — so the partial-sum order, and with it every downstream basis
/// bit, is identical at any `--threads` setting.
const COV_MAX_CHUNKS: usize = 16;
/// Minimum rows per chunk (don't split tiny inputs).
const COV_MIN_CHUNK_ROWS: usize = 512;

/// Accumulate the (uncentered) covariance `Σ_b x_b x_bᵀ / N` of `n`-dim
/// rows stored contiguously in `rows`.
pub fn covariance(rows: &[f32], n: usize) -> Vec<f64> {
    assert!(n > 0 && rows.len() % n == 0);
    let count = rows.len() / n;
    // parallel over deterministically-sized row-chunks, each
    // accumulating a private matrix; partials are then summed in chunk
    // order (deterministic)
    let chunk = count.div_ceil(COV_MAX_CHUNKS).max(COV_MIN_CHUNK_ROWS);
    let n_chunks = count.div_ceil(chunk).max(1);
    let partials = parallel::par_map(n_chunks, |t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(count);
        let mut acc = vec![0.0f64; n * n];
        for r in lo..hi {
            let row = &rows[r * n..(r + 1) * n];
            // rank-1 update, upper triangle only
            for i in 0..n {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let acc_row = &mut acc[i * n..(i + 1) * n];
                for j in i..n {
                    acc_row[j] += xi * row[j] as f64;
                }
            }
        }
        acc
    });
    let mut cov = vec![0.0f64; n * n];
    for p in partials {
        for (c, v) in cov.iter_mut().zip(p) {
            *c += v;
        }
    }
    let scale = 1.0 / count.max(1) as f64;
    for i in 0..n {
        for j in i..n {
            let v = cov[i * n + j] * scale;
            cov[i * n + j] = v;
            cov[j * n + i] = v;
        }
    }
    cov
}

/// A fitted PCA basis.
#[derive(Debug, Clone)]
pub struct Pca {
    /// Row-major `n x n`; column `j` is the j-th basis vector (descending
    /// eigenvalue) — the paper's `U`.
    pub basis: Vec<f64>,
    /// Descending eigenvalues.
    pub eigenvalues: Vec<f64>,
    pub n: usize,
}

impl Pca {
    /// Fit on residual rows (each `n` long, concatenated).
    pub fn fit(rows: &[f32], n: usize) -> Result<Self> {
        let cov = covariance(rows, n);
        let (eigenvalues, basis) = eigh_symmetric(&cov, n)?;
        Ok(Self { basis, eigenvalues, n })
    }

    /// Project a residual onto the basis: `c = Uᵀ x` (Eq. 9).
    pub fn project(&self, x: &[f32], c: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(c.len(), n);
        for j in 0..n {
            let mut acc = 0.0;
            for i in 0..n {
                acc += self.basis[i * n + j] * x[i] as f64;
            }
            c[j] = acc;
        }
    }

    /// Accumulate `x += Σ_{j in sel} c_j u_j` (Eq. 10 correction).
    pub fn add_reconstruction(&self, sel: &[(usize, f64)], x: &mut [f32]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        for &(j, cj) in sel {
            for i in 0..n {
                x[i] += (self.basis[i * n + j] * cj) as f32;
            }
        }
    }

    /// Serialize basis as f32 bytes (stored in the archive; §II-E counts
    /// it toward the compressed size).
    pub fn basis_f32_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.basis.len() * 4);
        for &v in &self.basis {
            out.extend_from_slice(&(v as f32).to_le_bytes());
        }
        out
    }

    /// Deserialize (inverse of [`Self::basis_f32_bytes`]).
    pub fn from_f32_bytes(bytes: &[u8], n: usize) -> Result<Self> {
        anyhow::ensure!(bytes.len() == n * n * 4, "basis byte length");
        let basis: Vec<f64> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64)
            .collect();
        Ok(Self { basis, eigenvalues: vec![0.0; n], n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synthetic_rows(n: usize, count: usize, rank: usize, seed: u64) -> Vec<f32> {
        // low-rank structure + small noise
        let mut rng = Rng::new(seed);
        let dirs: Vec<f64> = (0..rank * n).map(|_| rng.normal()).collect();
        let mut rows = vec![0f32; count * n];
        for r in 0..count {
            for k in 0..rank {
                let w = rng.normal() * (rank - k) as f64; // decreasing power
                for i in 0..n {
                    rows[r * n + i] += (w * dirs[k * n + i]) as f32;
                }
            }
            for i in 0..n {
                rows[r * n + i] += (0.01 * rng.normal()) as f32;
            }
        }
        rows
    }

    #[test]
    fn covariance_matches_naive() {
        let n = 6;
        let rows = synthetic_rows(n, 40, 2, 3);
        let cov = covariance(&rows, n);
        // naive check at a few entries
        let count = rows.len() / n;
        for &(i, j) in &[(0usize, 0usize), (1, 4), (5, 5), (2, 3)] {
            let mut acc = 0.0;
            for r in 0..count {
                acc += rows[r * n + i] as f64 * rows[r * n + j] as f64;
            }
            acc /= count as f64;
            assert!((cov[i * n + j] - acc).abs() < 1e-9);
            assert_eq!(cov[i * n + j], cov[j * n + i]);
        }
    }

    #[test]
    fn full_projection_recovers_exactly() {
        let n = 10;
        let rows = synthetic_rows(n, 50, 3, 7);
        let pca = Pca::fit(&rows, n).unwrap();
        let x = &rows[20 * n..21 * n];
        let mut c = vec![0.0; n];
        pca.project(x, &mut c);
        // full reconstruction U c == x (complete basis)
        let mut rec = vec![0f32; n];
        let sel: Vec<(usize, f64)> = (0..n).map(|j| (j, c[j])).collect();
        pca.add_reconstruction(&sel, &mut rec);
        for i in 0..n {
            assert!((rec[i] - x[i]).abs() < 1e-3, "{} vs {}", rec[i], x[i]);
        }
    }

    #[test]
    fn leading_coefficients_capture_most_energy() {
        let n = 12;
        let rank = 2;
        let rows = synthetic_rows(n, 200, rank, 11);
        let pca = Pca::fit(&rows, n).unwrap();
        // eigenvalues concentrated in the first `rank`
        let total: f64 = pca.eigenvalues.iter().sum();
        let lead: f64 = pca.eigenvalues[..rank].iter().sum();
        assert!(lead / total > 0.95, "lead fraction {}", lead / total);
        // projecting a row: top-rank coefficients shrink the residual a lot
        let x = &rows[0..n];
        let mut c = vec![0.0; n];
        pca.project(x, &mut c);
        let mut corrected: Vec<f32> = x.iter().map(|&v| -v).collect(); // -(x) + Uc ≈ 0
        let sel: Vec<(usize, f64)> = (0..rank).map(|j| (j, c[j])).collect();
        pca.add_reconstruction(&sel, &mut corrected);
        let before = crate::linalg::norm2_f32(x);
        let after = crate::linalg::norm2_f32(&corrected);
        assert!(after < 0.3 * before, "{after} vs {before}");
    }

    #[test]
    fn basis_serialization_round_trip() {
        let n = 8;
        let rows = synthetic_rows(n, 30, 2, 13);
        let pca = Pca::fit(&rows, n).unwrap();
        let bytes = pca.basis_f32_bytes();
        assert_eq!(bytes.len(), n * n * 4);
        let back = Pca::from_f32_bytes(&bytes, n).unwrap();
        for (a, b) in pca.basis.iter().zip(&back.basis) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!(Pca::from_f32_bytes(&bytes[1..], n).is_err());
    }
}
