//! Model parameter store + checkpointing.
//!
//! Parameters live as the **flat f32 vector** the AOT entry points take
//! (layout recorded in the manifest; packing logic lives on the python
//! side — rust only needs the total dim and, for diagnostics, the layout
//! names). Adam state (m, v, step) is carried alongside so training can
//! resume.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::runtime::{HostTensor, Runtime};
use crate::Result;
use anyhow::{bail, ensure, Context};

const MAGIC: &[u8; 4] = b"ARCK";
const VERSION: u16 = 1;

/// Parameters + optimizer state for one model group.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub group: String,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl ParamStore {
    /// Initialize from the group's AOT `init` entry (Glorot, seeded on the
    /// python side by the group name — deterministic across runs).
    pub fn init(rt: &Runtime, group: &str) -> Result<Self> {
        let pdim = rt.param_dim(group)?;
        let init = rt.load(group, "init")?;
        let out = init.run(&[])?;
        let theta = out.into_iter().next().unwrap().data;
        ensure!(theta.len() == pdim, "init returned {} != {pdim}", theta.len());
        Ok(Self {
            group: group.to_string(),
            m: vec![0.0; pdim],
            v: vec![0.0; pdim],
            step: 0.0,
            theta,
        })
    }

    pub fn param_dim(&self) -> usize {
        self.theta.len()
    }

    /// The four optimizer-state tensors in train_step input order.
    pub fn as_inputs(&self) -> [HostTensor; 4] {
        [
            HostTensor::vec(self.theta.clone()),
            HostTensor::vec(self.m.clone()),
            HostTensor::vec(self.v.clone()),
            HostTensor::scalar(self.step),
        ]
    }

    /// Absorb train_step outputs `(theta', m', v', t', loss)`; returns loss.
    pub fn absorb(&mut self, mut outs: Vec<HostTensor>) -> Result<f32> {
        ensure!(outs.len() == 5, "train_step returned {} outputs", outs.len());
        let loss = outs.pop().unwrap().scalar_value();
        self.step = outs.pop().unwrap().scalar_value();
        self.v = outs.pop().unwrap().data;
        self.m = outs.pop().unwrap().data;
        self.theta = outs.pop().unwrap().data;
        Ok(loss)
    }

    /// Save a checkpoint (binary; magic + group + θ/m/v/step).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        let gb = self.group.as_bytes();
        w.write_all(&(gb.len() as u32).to_le_bytes())?;
        w.write_all(gb)?;
        w.write_all(&(self.theta.len() as u64).to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        for vec in [&self.theta, &self.m, &self.v] {
            for &x in vec {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        w.flush()?;
        Ok(())
    }

    /// Load a checkpoint; verifies the group name matches.
    pub fn load(path: impl AsRef<Path>, expect_group: &str) -> Result<Self> {
        let path = path.as_ref();
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        ensure!(&magic == MAGIC, "{}: not a checkpoint", path.display());
        let mut b2 = [0u8; 2];
        r.read_exact(&mut b2)?;
        ensure!(u16::from_le_bytes(b2) == VERSION, "checkpoint version");
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let glen = u32::from_le_bytes(b4) as usize;
        let mut gb = vec![0u8; glen];
        r.read_exact(&mut gb)?;
        let group = String::from_utf8(gb)?;
        if group != expect_group {
            bail!(
                "checkpoint {} is for group {group:?}, expected {expect_group:?}",
                path.display()
            );
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let pdim = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b4)?;
        let step = f32::from_le_bytes(b4);
        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect())
        };
        let theta = read_vec(pdim)?;
        let m = read_vec(pdim)?;
        let v = read_vec(pdim)?;
        Ok(Self { group, theta, m, v, step })
    }

    /// Canonical checkpoint path for a group.
    pub fn default_path(dir: impl AsRef<Path>, group: &str) -> std::path::PathBuf {
        dir.as_ref().join(format!("{group}.ckpt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore {
            group: "test_group".into(),
            theta: (0..100).map(|i| i as f32 * 0.1).collect(),
            m: vec![0.5; 100],
            v: vec![0.25; 100],
            step: 42.0,
        }
    }

    #[test]
    fn checkpoint_round_trip() {
        let dir = std::env::temp_dir().join("attn_reduce_ckpt_test");
        let path = dir.join("test_group.ckpt");
        let s = store();
        s.save(&path).unwrap();
        let back = ParamStore::load(&path, "test_group").unwrap();
        assert_eq!(back.theta, s.theta);
        assert_eq!(back.m, s.m);
        assert_eq!(back.v, s.v);
        assert_eq!(back.step, s.step);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn group_mismatch_rejected() {
        let dir = std::env::temp_dir().join("attn_reduce_ckpt_test2");
        let path = dir.join("x.ckpt");
        store().save(&path).unwrap();
        assert!(ParamStore::load(&path, "other_group").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn absorb_updates_state() {
        let mut s = store();
        let outs = vec![
            HostTensor::vec(vec![1.0; 100]),
            HostTensor::vec(vec![2.0; 100]),
            HostTensor::vec(vec![3.0; 100]),
            HostTensor::scalar(43.0),
            HostTensor::scalar(0.125),
        ];
        let loss = s.absorb(outs).unwrap();
        assert_eq!(loss, 0.125);
        assert_eq!(s.step, 43.0);
        assert_eq!(s.theta[0], 1.0);
        assert_eq!(s.m[0], 2.0);
        assert_eq!(s.v[0], 3.0);
    }

    #[test]
    fn absorb_rejects_wrong_arity() {
        let mut s = store();
        assert!(s.absorb(vec![HostTensor::scalar(1.0)]).is_err());
    }
}
