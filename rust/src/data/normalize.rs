//! Normalization (paper §III-A): z-score over the field (E3SM, XGC) or
//! per-species mean-0 / range-1 (S3D). Stats are stored in the archive
//! header so decompression can denormalize.

use crate::config::Normalization;
use crate::tensor::Tensor;
use crate::util::json::{self, Value};
use crate::Result;

/// Per-channel affine stats: `x_norm = (x - offset) / scale`.
#[derive(Debug, Clone, PartialEq)]
pub struct NormStats {
    pub kind: Normalization,
    /// One `(offset, scale)` per channel (1 channel for z-score, one per
    /// species for S3D). Scale is guaranteed non-zero.
    pub channels: Vec<(f64, f64)>,
}

impl NormStats {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("kind", json::s(self.kind.name())),
            (
                "channels",
                Value::Arr(
                    self.channels
                        .iter()
                        .map(|&(o, s)| Value::Arr(vec![Value::Num(o), Value::Num(s)]))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let kind = Normalization::parse(v.req("kind")?.as_str().unwrap_or(""))?;
        let channels = v
            .req("channels")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("channels not array"))?
            .iter()
            .map(|pair| {
                let o = pair.idx(0).and_then(|x| x.as_f64()).unwrap_or(0.0);
                let s = pair.idx(1).and_then(|x| x.as_f64()).unwrap_or(1.0);
                (o, s)
            })
            .collect();
        Ok(Self { kind, channels })
    }
}

/// Fits and applies normalization.
pub struct Normalizer;

impl Normalizer {
    /// Fit stats on `t`. For [`Normalization::PerSpeciesMeanRange`] the
    /// first dim is the species/channel axis.
    pub fn fit(kind: Normalization, t: &Tensor) -> NormStats {
        match kind {
            Normalization::ZScore => {
                let mean = t.mean();
                let std = t.std().max(1e-30);
                NormStats { kind, channels: vec![(mean, std)] }
            }
            Normalization::PerSpeciesMeanRange => {
                let species = t.shape()[0];
                let per = t.len() / species;
                let channels = (0..species)
                    .map(|s| {
                        let slice = &t.data()[s * per..(s + 1) * per];
                        let mean =
                            slice.iter().map(|&x| x as f64).sum::<f64>() / per as f64;
                        let mut lo = f64::INFINITY;
                        let mut hi = f64::NEG_INFINITY;
                        for &x in slice {
                            lo = lo.min(x as f64);
                            hi = hi.max(x as f64);
                        }
                        let range = (hi - lo).max(1e-30);
                        (mean, range)
                    })
                    .collect();
                NormStats { kind, channels }
            }
        }
    }

    /// Normalize in place.
    pub fn apply(stats: &NormStats, t: &mut Tensor) {
        match stats.kind {
            Normalization::ZScore => {
                let (o, s) = stats.channels[0];
                for v in t.data_mut() {
                    *v = ((*v as f64 - o) / s) as f32;
                }
            }
            Normalization::PerSpeciesMeanRange => {
                let species = stats.channels.len();
                let per = t.len() / species;
                for (si, &(o, s)) in stats.channels.iter().enumerate() {
                    for v in &mut t.data_mut()[si * per..(si + 1) * per] {
                        *v = ((*v as f64 - o) / s) as f32;
                    }
                }
            }
        }
    }

    /// Invert normalization in place.
    pub fn invert(stats: &NormStats, t: &mut Tensor) {
        match stats.kind {
            Normalization::ZScore => {
                let (o, s) = stats.channels[0];
                for v in t.data_mut() {
                    *v = (*v as f64 * s + o) as f32;
                }
            }
            Normalization::PerSpeciesMeanRange => {
                let species = stats.channels.len();
                let per = t.len() / species;
                for (si, &(o, s)) in stats.channels.iter().enumerate() {
                    for v in &mut t.data_mut()[si * per..(si + 1) * per] {
                        *v = (*v as f64 * s + o) as f32;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_tensor(shape: Vec<usize>, seed: u64, scale: f64, off: f64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n = shape.iter().product();
        Tensor::new(
            shape,
            (0..n).map(|_| (rng.normal() * scale + off) as f32).collect(),
        )
    }

    #[test]
    fn zscore_standardizes_and_inverts() {
        let mut t = random_tensor(vec![10, 20], 1, 250.0, 101_000.0);
        let orig = t.clone();
        let stats = Normalizer::fit(Normalization::ZScore, &t);
        Normalizer::apply(&stats, &mut t);
        assert!(t.mean().abs() < 1e-3);
        assert!((t.std() - 1.0).abs() < 1e-3);
        Normalizer::invert(&stats, &mut t);
        for (a, b) in t.data().iter().zip(orig.data()) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}"); // f32 at 1e5 magnitude
        }
    }

    #[test]
    fn per_species_mean0_range1() {
        let mut t = Tensor::new(
            vec![2, 4],
            vec![0.0, 1.0, 2.0, 3.0, 100.0, 200.0, 300.0, 400.0],
        );
        let stats = Normalizer::fit(Normalization::PerSpeciesMeanRange, &t);
        Normalizer::apply(&stats, &mut t);
        for s in 0..2 {
            let slice = &t.data()[s * 4..(s + 1) * 4];
            let mean: f32 = slice.iter().sum::<f32>() / 4.0;
            let range = slice.iter().cloned().fold(f32::MIN, f32::max)
                - slice.iter().cloned().fold(f32::MAX, f32::min);
            assert!(mean.abs() < 1e-6, "species {s} mean {mean}");
            assert!((range - 1.0).abs() < 1e-6, "species {s} range {range}");
        }
    }

    #[test]
    fn constant_channel_does_not_divide_by_zero() {
        let mut t = Tensor::new(vec![1, 4], vec![5.0; 4]);
        let stats = Normalizer::fit(Normalization::PerSpeciesMeanRange, &t);
        Normalizer::apply(&stats, &mut t);
        assert!(t.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stats_json_round_trip() {
        let t = random_tensor(vec![3, 8], 2, 1.0, 0.0);
        let stats = Normalizer::fit(Normalization::PerSpeciesMeanRange, &t);
        let v = stats.to_json();
        let back = NormStats::from_json(&Value::parse(&v.to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back.kind, stats.kind);
        assert_eq!(back.channels.len(), stats.channels.len());
        for (a, b) in back.channels.iter().zip(&stats.channels) {
            assert!((a.0 - b.0).abs() < 1e-12 && (a.1 - b.1).abs() < 1e-12);
        }
    }
}
