//! Raw little-endian f32 file I/O — the interchange format scientific
//! codes (and SZ3/ZFP CLIs) use for field dumps.

use std::io::{BufReader, Read};
use std::path::Path;

use crate::tensor::Tensor;
use crate::util::durable;
use crate::Result;
use anyhow::{ensure, Context};

/// Write a tensor as raw little-endian f32 (shape is external metadata).
/// Atomic like every other output in the crate: the bytes land under a
/// temp sibling, are fsynced, and only then renamed onto `path` — a
/// crash mid-write can never leave a truncated field under the final
/// name.
pub fn write_f32_file(path: impl AsRef<Path>, t: &Tensor) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = Vec::with_capacity(t.len() * 4);
    for &v in t.data() {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    durable::write_atomic(path, &bytes)
        .with_context(|| format!("writing {}", path.display()))
}

/// Read a raw little-endian f32 file into a tensor of the given shape.
pub fn read_f32_file(path: impl AsRef<Path>, shape: Vec<usize>) -> Result<Tensor> {
    let path = path.as_ref();
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let expected: usize = shape.iter().product();
    let mut r = BufReader::new(f);
    let mut bytes = Vec::with_capacity(expected * 4);
    r.read_to_end(&mut bytes)?;
    ensure!(
        bytes.len() == expected * 4,
        "{}: {} bytes != shape {:?} ({} bytes)",
        path.display(),
        bytes.len(),
        shape,
        expected * 4
    );
    let data = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Tensor::new(shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("attn_reduce_io_test");
        let path = dir.join("t.f32");
        let t = Tensor::new(vec![2, 3], vec![1.5, -2.25, 0.0, f32::MIN, f32::MAX, 3.0]);
        write_f32_file(&path, &t).unwrap();
        let back = read_f32_file(&path, vec![2, 3]).unwrap();
        assert_eq!(back.data(), t.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_shape_rejected() {
        let dir = std::env::temp_dir().join("attn_reduce_io_test2");
        let path = dir.join("t.f32");
        write_f32_file(&path, &Tensor::from_vec(vec![1.0, 2.0])).unwrap();
        assert!(read_f32_file(&path, vec![3]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
