//! Raw little-endian f32 file I/O — the interchange format scientific
//! codes (and SZ3/ZFP CLIs) use for field dumps.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::tensor::Tensor;
use crate::Result;
use anyhow::{ensure, Context};

/// Write a tensor as raw little-endian f32 (shape is external metadata).
pub fn write_f32_file(path: impl AsRef<Path>, t: &Tensor) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for &v in t.data() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a raw little-endian f32 file into a tensor of the given shape.
pub fn read_f32_file(path: impl AsRef<Path>, shape: Vec<usize>) -> Result<Tensor> {
    let path = path.as_ref();
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let expected: usize = shape.iter().product();
    let mut r = BufReader::new(f);
    let mut bytes = Vec::with_capacity(expected * 4);
    r.read_to_end(&mut bytes)?;
    ensure!(
        bytes.len() == expected * 4,
        "{}: {} bytes != shape {:?} ({} bytes)",
        path.display(),
        bytes.len(),
        shape,
        expected * 4
    );
    let data = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Tensor::new(shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("attn_reduce_io_test");
        let path = dir.join("t.f32");
        let t = Tensor::new(vec![2, 3], vec![1.5, -2.25, 0.0, f32::MIN, f32::MAX, 3.0]);
        write_f32_file(&path, &t).unwrap();
        let back = read_f32_file(&path, vec![2, 3]).unwrap();
        assert_eq!(back.data(), t.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_shape_rejected() {
        let dir = std::env::temp_dir().join("attn_reduce_io_test2");
        let path = dir.join("t.f32");
        write_f32_file(&path, &Tensor::from_vec(vec![1.0, 2.0])).unwrap();
        assert!(read_f32_file(&path, vec![3]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
