//! Blocking + hyper-blocking (paper §II, Fig. 1 left).
//!
//! The field is tiled by the AE block shape (ceil division; edge blocks
//! zero-padded). Blocks are then grouped into hyper-blocks of `k`
//! consecutive blocks along the configured `hyper_axis` (time for
//! S3D/E3SM, toroidal plane for XGC). If the block count along that axis
//! is not a multiple of `k`, the last group is padded with zero blocks;
//! the [`BlockLayout`] records validity so scatter ignores padding and CR
//! accounting can skip it.

use crate::config::DatasetConfig;
use crate::tensor::{extract_block, scatter_block, Tensor};

/// Resolved blocking geometry for one dataset config.
#[derive(Debug, Clone)]
pub struct Blocking {
    pub dims: Vec<usize>,
    pub ae_block: Vec<usize>,
    pub k: usize,
    pub hyper_axis: usize,
    /// Blocks along each dim (ceil).
    pub counts: Vec<usize>,
    /// Hyper-groups along the hyper axis (ceil of counts[axis]/k).
    pub hyper_groups: usize,
}

/// Where hyper-block `h`, slot `j` lives in the field; `None` = padding.
pub type BlockLayout = Vec<Vec<Option<Vec<usize>>>>;

impl Blocking {
    pub fn new(cfg: &DatasetConfig) -> Self {
        let counts: Vec<usize> = cfg
            .dims
            .iter()
            .zip(&cfg.ae_block)
            .map(|(&d, &b)| d.div_ceil(b))
            .collect();
        let hyper_groups = counts[cfg.hyper_axis].div_ceil(cfg.k);
        Self {
            dims: cfg.dims.clone(),
            ae_block: cfg.ae_block.clone(),
            k: cfg.k,
            hyper_axis: cfg.hyper_axis,
            counts,
            hyper_groups,
        }
    }

    pub fn block_dim(&self) -> usize {
        self.ae_block.iter().product()
    }

    /// Total hyper-blocks (including ones whose tail slots are padding).
    pub fn num_hyperblocks(&self) -> usize {
        let others: usize = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(d, _)| d != self.hyper_axis)
            .map(|(_, &c)| c)
            .product();
        others * self.hyper_groups
    }

    /// Number of *valid* (non-padding) blocks.
    pub fn num_blocks(&self) -> usize {
        self.counts.iter().product()
    }

    /// Origin of hyper-block `h`, slot `j` (`None` if padding).
    ///
    /// Hyper-blocks enumerate the non-hyper axes row-major (outer) with the
    /// hyper-group index innermost; slot `j` advances along the hyper axis.
    pub fn origin(&self, h: usize, j: usize) -> Option<Vec<usize>> {
        assert!(j < self.k);
        let rank = self.dims.len();
        let groups = self.hyper_groups;
        let g = h % groups;
        let mut rest = h / groups;
        // decode the non-hyper block coordinates row-major
        let mut coord = vec![0usize; rank];
        for d in (0..rank).rev() {
            if d == self.hyper_axis {
                continue;
            }
            coord[d] = rest % self.counts[d];
            rest /= self.counts[d];
        }
        let axis_idx = g * self.k + j;
        if axis_idx >= self.counts[self.hyper_axis] {
            return None; // padding slot
        }
        coord[self.hyper_axis] = axis_idx;
        Some(
            coord
                .iter()
                .zip(&self.ae_block)
                .map(|(&c, &b)| c * b)
                .collect(),
        )
    }

    /// Full layout table `[num_hyperblocks][k]`.
    pub fn layout(&self) -> BlockLayout {
        (0..self.num_hyperblocks())
            .map(|h| (0..self.k).map(|j| self.origin(h, j)).collect())
            .collect()
    }

    /// Extract hyper-blocks `[h0, h0+n)` into a contiguous `[n, k, bd]`
    /// buffer (padding slots are zero).
    pub fn gather(&self, t: &Tensor, h0: usize, n: usize, out: &mut [f32]) {
        let bd = self.block_dim();
        assert_eq!(out.len(), n * self.k * bd);
        out.fill(0.0);
        for hi in 0..n {
            let h = h0 + hi;
            if h >= self.num_hyperblocks() {
                continue; // batch padding beyond the dataset
            }
            for j in 0..self.k {
                if let Some(origin) = self.origin(h, j) {
                    let slot = &mut out[(hi * self.k + j) * bd..(hi * self.k + j + 1) * bd];
                    extract_block(t, &origin, &self.ae_block, slot);
                }
            }
        }
    }

    /// Scatter a `[n, k, bd]` buffer back (inverse of [`Self::gather`];
    /// padding slots are ignored).
    pub fn scatter(&self, t: &mut Tensor, h0: usize, n: usize, data: &[f32]) {
        let bd = self.block_dim();
        assert_eq!(data.len(), n * self.k * bd);
        for hi in 0..n {
            let h = h0 + hi;
            if h >= self.num_hyperblocks() {
                continue;
            }
            for j in 0..self.k {
                if let Some(origin) = self.origin(h, j) {
                    let slot = &data[(hi * self.k + j) * bd..(hi * self.k + j + 1) * bd];
                    scatter_block(t, &origin, &self.ae_block, slot);
                }
            }
        }
    }

    /// Is slot `j` of hyper-block `h` a real block?
    pub fn is_valid(&self, h: usize, j: usize) -> bool {
        h < self.num_hyperblocks() && self.origin(h, j).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{dataset_preset, DatasetKind, Normalization, Scale};

    fn tiny_cfg() -> DatasetConfig {
        DatasetConfig {
            kind: DatasetKind::E3sm,
            dims: vec![12, 8, 8],
            ae_block: vec![2, 4, 4],
            k: 3,
            hyper_axis: 0,
            gae_block: vec![1, 4, 4],
            normalization: Normalization::ZScore,
            seed: 0,
        }
    }

    #[test]
    fn counts_and_hyperblocks() {
        let b = Blocking::new(&tiny_cfg());
        assert_eq!(b.counts, vec![6, 2, 2]);
        assert_eq!(b.hyper_groups, 2);
        assert_eq!(b.num_hyperblocks(), 8);
        assert_eq!(b.num_blocks(), 24);
        assert_eq!(b.block_dim(), 32);
    }

    #[test]
    fn every_block_appears_exactly_once() {
        let b = Blocking::new(&tiny_cfg());
        let mut seen = std::collections::HashSet::new();
        for h in 0..b.num_hyperblocks() {
            for j in 0..b.k {
                if let Some(o) = b.origin(h, j) {
                    assert!(seen.insert(o.clone()), "duplicate origin {o:?}");
                }
            }
        }
        assert_eq!(seen.len(), b.num_blocks());
    }

    #[test]
    fn padding_when_axis_not_divisible() {
        // 5 blocks along the hyper axis, k=3 -> group 1 has one padding slot
        let mut cfg = tiny_cfg();
        cfg.dims = vec![10, 8, 8]; // 5 blocks of 2
        let b = Blocking::new(&cfg);
        assert_eq!(b.hyper_groups, 2);
        let padded = (0..b.num_hyperblocks())
            .flat_map(|h| (0..b.k).map(move |j| (h, j)))
            .filter(|&(h, j)| !b.is_valid(h, j))
            .count();
        assert_eq!(padded, 4); // (6-5) padding slot x 4 spatial tiles
    }

    #[test]
    fn gather_scatter_round_trip() {
        let cfg = tiny_cfg();
        let b = Blocking::new(&cfg);
        let n: usize = cfg.dims.iter().product();
        let t = Tensor::new(cfg.dims.clone(), (0..n).map(|i| i as f32).collect());
        let nh = b.num_hyperblocks();
        let mut buf = vec![0f32; nh * b.k * b.block_dim()];
        b.gather(&t, 0, nh, &mut buf);
        let mut t2 = Tensor::zeros(cfg.dims.clone());
        b.scatter(&mut t2, 0, nh, &buf);
        assert_eq!(t.data(), t2.data());
    }

    #[test]
    fn gather_beyond_end_zero_fills() {
        let cfg = tiny_cfg();
        let b = Blocking::new(&cfg);
        let t = Tensor::zeros(cfg.dims.clone());
        let mut buf = vec![7f32; 2 * b.k * b.block_dim()];
        b.gather(&t, b.num_hyperblocks() - 1, 2, &mut buf);
        // the second hyperblock in the batch is past the end -> zeros
        assert!(buf[b.k * b.block_dim()..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn preset_geometry_matches_paper() {
        // s3d bench: 50/5 = 10 temporal blocks = exactly one hyper-group
        let b = Blocking::new(&dataset_preset(DatasetKind::S3d, Scale::Bench));
        assert_eq!(b.counts[1], 10);
        assert_eq!(b.hyper_groups, 1);
        // xgc: 8 planes = k
        let b = Blocking::new(&dataset_preset(DatasetKind::Xgc, Scale::Bench));
        assert_eq!(b.counts[0], 8);
        assert_eq!(b.hyper_groups, 1);
        assert_eq!(b.k, 8);
    }

    #[test]
    fn xgc_hyperblock_is_one_node_across_planes() {
        let b = Blocking::new(&dataset_preset(DatasetKind::Xgc, Scale::Smoke));
        // slot j of any hyper-block must differ only in the plane coord
        let o0 = b.origin(5, 0).unwrap();
        let o3 = b.origin(5, 3).unwrap();
        assert_eq!(o0[1..], o3[1..]);
        assert_eq!(o3[0] - o0[0], 3);
    }
}
