//! Blocking + hyper-blocking (paper §II, Fig. 1 left).
//!
//! The field is tiled by the AE block shape (ceil division; edge blocks
//! zero-padded). Blocks are then grouped into hyper-blocks of `k`
//! consecutive blocks along the configured `hyper_axis` (time for
//! S3D/E3SM, toroidal plane for XGC). If the block count along that axis
//! is not a multiple of `k`, the last group is padded with zero blocks;
//! the [`BlockLayout`] records validity so scatter ignores padding and CR
//! accounting can skip it.

use crate::config::DatasetConfig;
use crate::tensor::{extract_block, scatter_block, Tensor};
use crate::Result;
use anyhow::{bail, ensure};

/// Resolved blocking geometry for one dataset config.
#[derive(Debug, Clone)]
pub struct Blocking {
    pub dims: Vec<usize>,
    pub ae_block: Vec<usize>,
    pub k: usize,
    pub hyper_axis: usize,
    /// Blocks along each dim (ceil).
    pub counts: Vec<usize>,
    /// Hyper-groups along the hyper axis (ceil of counts[axis]/k).
    pub hyper_groups: usize,
}

/// Where hyper-block `h`, slot `j` lives in the field; `None` = padding.
pub type BlockLayout = Vec<Vec<Option<Vec<usize>>>>;

impl Blocking {
    pub fn new(cfg: &DatasetConfig) -> Self {
        let counts: Vec<usize> = cfg
            .dims
            .iter()
            .zip(&cfg.ae_block)
            .map(|(&d, &b)| d.div_ceil(b))
            .collect();
        let hyper_groups = counts[cfg.hyper_axis].div_ceil(cfg.k);
        Self {
            dims: cfg.dims.clone(),
            ae_block: cfg.ae_block.clone(),
            k: cfg.k,
            hyper_axis: cfg.hyper_axis,
            counts,
            hyper_groups,
        }
    }

    pub fn block_dim(&self) -> usize {
        self.ae_block.iter().product()
    }

    /// Total hyper-blocks (including ones whose tail slots are padding).
    pub fn num_hyperblocks(&self) -> usize {
        let others: usize = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(d, _)| d != self.hyper_axis)
            .map(|(_, &c)| c)
            .product();
        others * self.hyper_groups
    }

    /// Number of *valid* (non-padding) blocks.
    pub fn num_blocks(&self) -> usize {
        self.counts.iter().product()
    }

    /// Origin of hyper-block `h`, slot `j` (`None` if padding).
    ///
    /// Hyper-blocks enumerate the non-hyper axes row-major (outer) with the
    /// hyper-group index innermost; slot `j` advances along the hyper axis.
    pub fn origin(&self, h: usize, j: usize) -> Option<Vec<usize>> {
        assert!(j < self.k);
        let rank = self.dims.len();
        let groups = self.hyper_groups;
        let g = h % groups;
        let mut rest = h / groups;
        // decode the non-hyper block coordinates row-major
        let mut coord = vec![0usize; rank];
        for d in (0..rank).rev() {
            if d == self.hyper_axis {
                continue;
            }
            coord[d] = rest % self.counts[d];
            rest /= self.counts[d];
        }
        let axis_idx = g * self.k + j;
        if axis_idx >= self.counts[self.hyper_axis] {
            return None; // padding slot
        }
        coord[self.hyper_axis] = axis_idx;
        Some(
            coord
                .iter()
                .zip(&self.ae_block)
                .map(|(&c, &b)| c * b)
                .collect(),
        )
    }

    /// Full layout table `[num_hyperblocks][k]`.
    pub fn layout(&self) -> BlockLayout {
        (0..self.num_hyperblocks())
            .map(|h| (0..self.k).map(|j| self.origin(h, j)).collect())
            .collect()
    }

    /// Extract hyper-blocks `[h0, h0+n)` into a contiguous `[n, k, bd]`
    /// buffer (padding slots are zero).
    pub fn gather(&self, t: &Tensor, h0: usize, n: usize, out: &mut [f32]) {
        let bd = self.block_dim();
        assert_eq!(out.len(), n * self.k * bd);
        out.fill(0.0);
        for hi in 0..n {
            let h = h0 + hi;
            if h >= self.num_hyperblocks() {
                continue; // batch padding beyond the dataset
            }
            for j in 0..self.k {
                if let Some(origin) = self.origin(h, j) {
                    let slot = &mut out[(hi * self.k + j) * bd..(hi * self.k + j + 1) * bd];
                    extract_block(t, &origin, &self.ae_block, slot);
                }
            }
        }
    }

    /// Scatter a `[n, k, bd]` buffer back (inverse of [`Self::gather`];
    /// padding slots are ignored).
    pub fn scatter(&self, t: &mut Tensor, h0: usize, n: usize, data: &[f32]) {
        let bd = self.block_dim();
        assert_eq!(data.len(), n * self.k * bd);
        for hi in 0..n {
            let h = h0 + hi;
            if h >= self.num_hyperblocks() {
                continue;
            }
            for j in 0..self.k {
                if let Some(origin) = self.origin(h, j) {
                    let slot = &data[(hi * self.k + j) * bd..(hi * self.k + j + 1) * bd];
                    scatter_block(t, &origin, &self.ae_block, slot);
                }
            }
        }
    }

    /// Is slot `j` of hyper-block `h` a real block?
    pub fn is_valid(&self, h: usize, j: usize) -> bool {
        h < self.num_hyperblocks() && self.origin(h, j).is_some()
    }
}

// ---------------------------------------------------------------------------
// Regions of interest — the hyper-rectangles the Archive v3 block index
// lets consumers decode without touching the rest of the payload
// ---------------------------------------------------------------------------

/// A half-open hyper-rectangle `[lo, hi)` in a field's index space.
///
/// Scientific consumers (post-hoc analysis, visualization) read small
/// sub-regions of huge meshes; a `Region` names such a request. The CLI
/// spelling is one `lo:hi` pair per dimension: `extract --region
/// 0:8,16:48,0:64`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    pub lo: Vec<usize>,
    pub hi: Vec<usize>,
}

impl Region {
    /// A region from per-dim half-open bounds (every `lo < hi` required).
    pub fn new(lo: Vec<usize>, hi: Vec<usize>) -> Result<Self> {
        ensure!(lo.len() == hi.len(), "region lo/hi rank mismatch");
        ensure!(!lo.is_empty(), "region must have at least one dimension");
        for (d, (&l, &h)) in lo.iter().zip(&hi).enumerate() {
            ensure!(l < h, "region dim {d} is empty ({l}:{h})");
        }
        Ok(Self { lo, hi })
    }

    /// The region covering all of `dims`.
    pub fn full(dims: &[usize]) -> Self {
        Self { lo: vec![0; dims.len()], hi: dims.to_vec() }
    }

    /// Parse the CLI syntax `i0:i1,j0:j1,...` (one pair per dimension).
    pub fn parse(s: &str) -> Result<Self> {
        let mut lo = Vec::new();
        let mut hi = Vec::new();
        for part in s.split(',') {
            let Some((a, b)) = part.split_once(':') else {
                bail!("bad region component {part:?} (expected lo:hi)");
            };
            let l: usize = a
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad region bound {a:?} in {part:?}"))?;
            let h: usize = b
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad region bound {b:?} in {part:?}"))?;
            lo.push(l);
            hi.push(h);
        }
        Self::new(lo, hi)
    }

    pub fn rank(&self) -> usize {
        self.lo.len()
    }

    /// Per-dim extent `hi - lo`.
    pub fn shape(&self) -> Vec<usize> {
        self.lo.iter().zip(&self.hi).map(|(&l, &h)| h - l).collect()
    }

    pub fn n_points(&self) -> usize {
        self.shape().iter().product()
    }

    /// Does the region fit inside a field of shape `dims`?
    pub fn validate_in(&self, dims: &[usize]) -> Result<()> {
        ensure!(
            self.rank() == dims.len(),
            "region rank {} != field rank {}",
            self.rank(),
            dims.len()
        );
        for (d, (&h, &dim)) in self.hi.iter().zip(dims).enumerate() {
            ensure!(h <= dim, "region dim {d} ends at {h}, field has {dim}");
        }
        Ok(())
    }

    /// Does the region overlap the block at `origin` with shape `size`?
    pub fn intersects(&self, origin: &[usize], size: &[usize]) -> bool {
        origin
            .iter()
            .zip(size)
            .zip(self.lo.iter().zip(&self.hi))
            .all(|((&o, &s), (&l, &h))| o < h && o + s > l)
    }

    /// Copy the region out of a full-field tensor (row-major).
    pub fn crop(&self, t: &Tensor) -> Result<Tensor> {
        self.validate_in(t.shape())?;
        let shape = self.shape();
        let n = self.n_points();
        let rank = self.rank();
        let strides = t.strides();
        let mut data = Vec::with_capacity(n);
        // copy innermost-dim runs; iterate over the outer dims row-major
        let run = shape[rank - 1];
        let outer: usize = n / run;
        let mut idx = vec![0usize; rank];
        for _ in 0..outer {
            let mut pos = 0usize;
            for d in 0..rank - 1 {
                pos += (self.lo[d] + idx[d]) * strides[d];
            }
            pos += self.lo[rank - 1];
            data.extend_from_slice(&t.data()[pos..pos + run]);
            // advance the outer multi-index
            for d in (0..rank.saturating_sub(1)).rev() {
                idx[d] += 1;
                if idx[d] < shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        Ok(Tensor::new(shape, data))
    }
}

/// Row-major ids of the tiles (of shape `tile`, ceil-tiling `dims`) that
/// intersect `region` — the blocks a v3 region decode must touch.
pub fn region_tile_ids(dims: &[usize], tile: &[usize], region: &Region) -> Vec<usize> {
    assert_eq!(dims.len(), tile.len());
    assert_eq!(dims.len(), region.rank());
    let counts: Vec<usize> = dims.iter().zip(tile).map(|(&d, &b)| d.div_ceil(b)).collect();
    // per-dim tile-index ranges covered by the region
    let t_lo: Vec<usize> = region.lo.iter().zip(tile).map(|(&l, &b)| l / b).collect();
    let t_hi: Vec<usize> = region
        .hi
        .iter()
        .zip(tile)
        .zip(&counts)
        .map(|((&h, &b), &c)| h.div_ceil(b).min(c))
        .collect();
    let total: usize = t_lo.iter().zip(&t_hi).map(|(&l, &h)| h - l).product();
    let mut out = Vec::with_capacity(total);
    let rank = dims.len();
    let mut idx = t_lo.clone();
    for _ in 0..total {
        let mut id = 0usize;
        for d in 0..rank {
            id = id * counts[d] + idx[d];
        }
        out.push(id);
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < t_hi[d] {
                break;
            }
            idx[d] = t_lo[d];
        }
    }
    out
}

/// Scatter a decoded tile (at absolute `origin`, shape `size`, row-major
/// in `data`) into `dst`, which holds only `region` — the reassembly step
/// of a region decode. Positions outside the region are dropped, exactly
/// like [`scatter_block`] drops positions outside the field.
pub fn scatter_tile_into_region(
    dst: &mut Tensor,
    region: &Region,
    origin: &[usize],
    size: &[usize],
    data: &[f32],
) {
    let rank = region.rank();
    assert_eq!(origin.len(), rank);
    assert_eq!(size.len(), rank);
    assert_eq!(data.len(), size.iter().product::<usize>());
    assert_eq!(dst.shape(), &region.shape()[..]);
    let strides = dst.strides();
    let mut idx = vec![0usize; rank];
    for (oi, &val) in data.iter().enumerate() {
        let mut rem = oi;
        for d in (0..rank).rev() {
            idx[d] = rem % size[d];
            rem /= size[d];
        }
        let mut pos = 0usize;
        let mut inside = true;
        for d in 0..rank {
            let p = origin[d] + idx[d];
            if p < region.lo[d] || p >= region.hi[d] {
                inside = false;
                break;
            }
            pos += (p - region.lo[d]) * strides[d];
        }
        if inside {
            dst.data_mut()[pos] = val;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{dataset_preset, DatasetKind, Normalization, Scale};

    fn tiny_cfg() -> DatasetConfig {
        DatasetConfig {
            kind: DatasetKind::E3sm,
            dims: vec![12, 8, 8],
            ae_block: vec![2, 4, 4],
            k: 3,
            hyper_axis: 0,
            gae_block: vec![1, 4, 4],
            normalization: Normalization::ZScore,
            seed: 0,
        }
    }

    #[test]
    fn counts_and_hyperblocks() {
        let b = Blocking::new(&tiny_cfg());
        assert_eq!(b.counts, vec![6, 2, 2]);
        assert_eq!(b.hyper_groups, 2);
        assert_eq!(b.num_hyperblocks(), 8);
        assert_eq!(b.num_blocks(), 24);
        assert_eq!(b.block_dim(), 32);
    }

    #[test]
    fn every_block_appears_exactly_once() {
        let b = Blocking::new(&tiny_cfg());
        let mut seen = std::collections::HashSet::new();
        for h in 0..b.num_hyperblocks() {
            for j in 0..b.k {
                if let Some(o) = b.origin(h, j) {
                    assert!(seen.insert(o.clone()), "duplicate origin {o:?}");
                }
            }
        }
        assert_eq!(seen.len(), b.num_blocks());
    }

    #[test]
    fn padding_when_axis_not_divisible() {
        // 5 blocks along the hyper axis, k=3 -> group 1 has one padding slot
        let mut cfg = tiny_cfg();
        cfg.dims = vec![10, 8, 8]; // 5 blocks of 2
        let b = Blocking::new(&cfg);
        assert_eq!(b.hyper_groups, 2);
        let padded = (0..b.num_hyperblocks())
            .flat_map(|h| (0..b.k).map(move |j| (h, j)))
            .filter(|&(h, j)| !b.is_valid(h, j))
            .count();
        assert_eq!(padded, 4); // (6-5) padding slot x 4 spatial tiles
    }

    #[test]
    fn gather_scatter_round_trip() {
        let cfg = tiny_cfg();
        let b = Blocking::new(&cfg);
        let n: usize = cfg.dims.iter().product();
        let t = Tensor::new(cfg.dims.clone(), (0..n).map(|i| i as f32).collect());
        let nh = b.num_hyperblocks();
        let mut buf = vec![0f32; nh * b.k * b.block_dim()];
        b.gather(&t, 0, nh, &mut buf);
        let mut t2 = Tensor::zeros(cfg.dims.clone());
        b.scatter(&mut t2, 0, nh, &buf);
        assert_eq!(t.data(), t2.data());
    }

    #[test]
    fn gather_beyond_end_zero_fills() {
        let cfg = tiny_cfg();
        let b = Blocking::new(&cfg);
        let t = Tensor::zeros(cfg.dims.clone());
        let mut buf = vec![7f32; 2 * b.k * b.block_dim()];
        b.gather(&t, b.num_hyperblocks() - 1, 2, &mut buf);
        // the second hyperblock in the batch is past the end -> zeros
        assert!(buf[b.k * b.block_dim()..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn preset_geometry_matches_paper() {
        // s3d bench: 50/5 = 10 temporal blocks = exactly one hyper-group
        let b = Blocking::new(&dataset_preset(DatasetKind::S3d, Scale::Bench));
        assert_eq!(b.counts[1], 10);
        assert_eq!(b.hyper_groups, 1);
        // xgc: 8 planes = k
        let b = Blocking::new(&dataset_preset(DatasetKind::Xgc, Scale::Bench));
        assert_eq!(b.counts[0], 8);
        assert_eq!(b.hyper_groups, 1);
        assert_eq!(b.k, 8);
    }

    #[test]
    fn region_parse_and_validate() {
        let r = Region::parse("0:8,16:48").unwrap();
        assert_eq!(r.lo, vec![0, 16]);
        assert_eq!(r.hi, vec![8, 48]);
        assert_eq!(r.shape(), vec![8, 32]);
        assert_eq!(r.n_points(), 256);
        r.validate_in(&[8, 48]).unwrap();
        assert!(r.validate_in(&[8, 47]).is_err(), "out of bounds");
        assert!(r.validate_in(&[8, 48, 2]).is_err(), "rank mismatch");
        for bad in ["", "1:2,", "3:1", "2:2", "a:b", "1-2", "1:2,x:4"] {
            assert!(Region::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn region_crop_matches_naive_indexing() {
        let t = Tensor::new(vec![4, 5, 6], (0..120).map(|i| i as f32).collect());
        let r = Region::parse("1:3,2:5,0:4").unwrap();
        let c = r.crop(&t).unwrap();
        assert_eq!(c.shape(), &[2, 3, 4]);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..4 {
                    let want = ((i + 1) * 30 + (j + 2) * 6 + k) as f32;
                    assert_eq!(c.data()[i * 12 + j * 4 + k], want);
                }
            }
        }
        // full region is the identity
        let full = Region::full(t.shape()).crop(&t).unwrap();
        assert_eq!(full.data(), t.data());
    }

    #[test]
    fn region_tile_ids_cover_exactly_intersecting_tiles() {
        let dims = vec![10, 12];
        let tile = vec![4, 4];
        // tiles: 3 x 3 grid, row-major ids 0..9
        let r = Region::parse("5:9,0:5").unwrap();
        // rows 5..9 touch tile-rows 1..3; cols 0..5 touch tile-cols 0..2
        assert_eq!(region_tile_ids(&dims, &tile, &r), vec![3, 4, 6, 7]);
        // and matches the intersects() predicate over all origins
        let origins = crate::tensor::block_origins(&dims, &tile);
        let by_pred: Vec<usize> = origins
            .iter()
            .enumerate()
            .filter(|(_, o)| r.intersects(o, &tile))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(region_tile_ids(&dims, &tile, &r), by_pred);
        // full region selects every tile
        let full = Region::full(&dims);
        assert_eq!(region_tile_ids(&dims, &tile, &full).len(), origins.len());
    }

    #[test]
    fn scatter_into_region_reassembles_a_crop() {
        let dims = vec![9, 7];
        let tile = vec![4, 4];
        let t = Tensor::new(dims.clone(), (0..63).map(|i| i as f32).collect());
        let r = Region::parse("2:8,1:6").unwrap();
        let mut out = Tensor::zeros(r.shape());
        let origins = crate::tensor::block_origins(&dims, &tile);
        let mut buf = vec![0f32; 16];
        for id in region_tile_ids(&dims, &tile, &r) {
            extract_block(&t, &origins[id], &tile, &mut buf);
            scatter_tile_into_region(&mut out, &r, &origins[id], &tile, &buf);
        }
        assert_eq!(out.data(), r.crop(&t).unwrap().data());
    }

    #[test]
    fn xgc_hyperblock_is_one_node_across_planes() {
        let b = Blocking::new(&dataset_preset(DatasetKind::Xgc, Scale::Smoke));
        // slot j of any hyper-block must differ only in the plane coord
        let o0 = b.origin(5, 0).unwrap();
        let o3 = b.origin(5, 3).unwrap();
        assert_eq!(o0[1..], o3[1..]);
        assert_eq!(o3[0] - o0[0], 3);
    }
}
