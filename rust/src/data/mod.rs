//! Datasets: synthetic generators (DESIGN.md §4 substitutions for S3D /
//! E3SM / XGC), normalization, blocking/hyper-blocking, and raw f32 I/O.
//!
//! Each generator reproduces the *structure the method exploits* in the
//! real data — strong inter-species correlation (S3D tensors), smooth
//! spatiotemporal evolution (all three), and cross-section redundancy
//! (XGC) — at configurable scale. `Scale::Paper` emits the paper's full
//! dims.

mod blocking;
mod e3sm;
mod io;
mod normalize;
mod s3d;
pub mod timeseries;
mod xgc;

pub use blocking::{
    region_tile_ids, scatter_tile_into_region, BlockLayout, Blocking, Region,
};
pub use e3sm::generate_e3sm;
pub use io::{read_f32_file, write_f32_file};
pub use normalize::{NormStats, Normalizer};
pub use s3d::generate_s3d;
pub use xgc::generate_xgc;

use crate::config::{DatasetConfig, DatasetKind};
use crate::tensor::Tensor;

/// Generate the synthetic dataset described by `cfg`.
pub fn generate(cfg: &DatasetConfig) -> Tensor {
    match cfg.kind {
        DatasetKind::S3d => generate_s3d(&cfg.dims, cfg.seed),
        DatasetKind::E3sm => generate_e3sm(&cfg.dims, cfg.seed),
        DatasetKind::Xgc => generate_xgc(&cfg.dims, cfg.seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{dataset_preset, DatasetKind, Scale};

    #[test]
    fn generate_dispatches_all_kinds() {
        for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
            let cfg = dataset_preset(kind, Scale::Smoke);
            let t = generate(&cfg);
            assert_eq!(t.shape(), &cfg.dims[..]);
            assert!(t.data().iter().all(|v| v.is_finite()));
            assert!(t.range() > 0.0);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.data(), b.data());
    }
}
