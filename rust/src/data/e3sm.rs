//! Synthetic E3SM PSL surrogate (DESIGN.md §4).
//!
//! The real data is hourly sea-level pressure from a 25 km atmosphere run,
//! cube-to-sphere projected to `[t, lat, lon]`. PSL is globally smooth
//! with a zonal (latitude) base profile, synoptic-scale traveling waves,
//! a diurnal cycle, fixed terrain-like spatial bias, and weak red noise —
//! exactly the ingredients below.

use crate::tensor::Tensor;
use crate::util::parallel::par_map;
use crate::util::rng::Rng;

/// Generate `[t, h, w]` (pressure in Pa-like units ~ 101000 ± 3000).
pub fn generate_e3sm(dims: &[usize], seed: u64) -> Tensor {
    assert_eq!(dims.len(), 3, "e3sm dims are [t, h, w]");
    let (t, h, w) = (dims[0], dims[1], dims[2]);
    let mut rng = Rng::new(seed);
    let tau = std::f64::consts::TAU;

    // synoptic waves: zonal wavenumbers with eastward phase speeds
    struct Wave {
        kx: f64,
        ky: f64,
        speed: f64,
        amp: f64,
        phase: f64,
    }
    let waves: Vec<Wave> = (0..8)
        .map(|i| Wave {
            kx: (1 + i % 5) as f64,
            ky: (1 + i % 3) as f64,
            speed: rng.range(0.5, 3.0),
            amp: 400.0 / (1.0 + i as f64),
            phase: rng.range(0.0, tau),
        })
        .collect();

    // fixed terrain-like bias: a few smooth bumps
    let mut brng = rng.fork(2);
    let bumps: Vec<(f64, f64, f64, f64)> = (0..6)
        .map(|_| {
            (
                brng.uniform(),
                brng.uniform(),
                brng.range(0.05, 0.2),
                brng.range(-800.0, 800.0),
            )
        })
        .collect();

    // red noise: AR(1) in time per coarse cell, bilinearly upsampled
    let (gh, gw) = (h.div_ceil(8).max(2), w.div_ceil(8).max(2));
    let mut nrng = rng.fork(3);
    let mut red = vec![0.0f64; gh * gw];
    let mut red_frames: Vec<Vec<f64>> = Vec::with_capacity(t);
    // small-amplitude red noise: the PSL field's sub-synoptic residual is
    // tiny relative to the ~8000 Pa dynamic range (noise floor << the
    // paper's NRMSE targets; DESIGN.md §4)
    for _ in 0..t {
        for v in red.iter_mut() {
            *v = 0.95 * *v + 2.0 * nrng.normal();
        }
        red_frames.push(red.clone());
    }

    let plane = h * w;
    let frames: Vec<Vec<f32>> = par_map(t, |ti| {
        let tt = ti as f64;
        let mut frame = vec![0f32; plane];
        let rf = &red_frames[ti];
        for yi in 0..h {
            let lat = yi as f64 / (h - 1).max(1) as f64; // 0..1 (S->N)
            // zonal base: subtropical highs / subpolar lows
            let zonal = 101_000.0 + 1500.0 * (lat * tau).cos() - 900.0 * ((lat - 0.5) * 2.0 * tau).cos();
            for xi in 0..w {
                let lon = xi as f64 / w as f64;
                let mut v = zonal;
                // diurnal cycle (hourly timesteps, period 24)
                v += 120.0 * ((tt / 24.0 + lon) * tau).sin();
                for wv in &waves {
                    v += wv.amp
                        * ((wv.kx * lon + wv.ky * lat) * tau - wv.speed * tt * 0.05 * tau
                            + wv.phase)
                            .sin()
                        * (0.3 + 0.7 * (lat * std::f64::consts::PI).sin()); // mid-lat emphasis
                }
                for &(bx, by, bw, bamp) in &bumps {
                    let mut dx = (lon - bx).abs();
                    dx = dx.min(1.0 - dx); // periodic longitude
                    let d2 = dx * dx + (lat - by) * (lat - by);
                    v += bamp * (-d2 / (2.0 * bw * bw)).exp();
                }
                // upsample red noise bilinearly
                let gy = lat * (gh - 1) as f64;
                let gx = lon * (gw - 1) as f64;
                let (y0, x0) = (gy as usize, gx as usize);
                let (y1, x1) = ((y0 + 1).min(gh - 1), (x0 + 1).min(gw - 1));
                let (fy, fx) = (gy - y0 as f64, gx - x0 as f64);
                let n = rf[y0 * gw + x0] * (1.0 - fy) * (1.0 - fx)
                    + rf[y0 * gw + x1] * (1.0 - fy) * fx
                    + rf[y1 * gw + x0] * fy * (1.0 - fx)
                    + rf[y1 * gw + x1] * fy * fx;
                v += n;
                frame[yi * w + xi] = v as f32;
            }
        }
        frame
    });

    let mut data = Vec::with_capacity(t * plane);
    for f in frames {
        data.extend(f);
    }
    Tensor::new(dims.to_vec(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realistic_pressure_range() {
        let t = generate_e3sm(&[6, 24, 48], 1);
        assert!(t.min() > 90_000.0, "min {}", t.min());
        assert!(t.max() < 112_000.0, "max {}", t.max());
        assert!((t.mean() - 101_000.0).abs() < 3_000.0, "mean {}", t.mean());
    }

    #[test]
    fn deterministic() {
        let a = generate_e3sm(&[4, 16, 32], 7);
        let b = generate_e3sm(&[4, 16, 32], 7);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn spatially_smooth() {
        // neighbor diffs tiny vs field range
        let t = generate_e3sm(&[2, 32, 64], 3);
        let w = 64;
        let mut max_step = 0f32;
        let frame = &t.data()[0..32 * 64];
        for y in 0..32 {
            for x in 0..w - 1 {
                max_step = max_step.max((frame[y * w + x + 1] - frame[y * w + x]).abs());
            }
        }
        assert!(max_step < 0.15 * t.range(), "{max_step} vs {}", t.range());
    }

    #[test]
    fn temporally_correlated() {
        let t = generate_e3sm(&[12, 16, 32], 5);
        let plane = 16 * 32;
        let d01: f64 = (0..plane)
            .map(|i| (t.data()[i] - t.data()[plane + i]).abs() as f64)
            .sum();
        let d0n: f64 = (0..plane)
            .map(|i| (t.data()[i] - t.data()[11 * plane + i]).abs() as f64)
            .sum();
        assert!(d01 < d0n, "adjacent {d01} vs distant {d0n}");
    }
}
