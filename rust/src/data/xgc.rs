//! Synthetic XGC F-data surrogate (DESIGN.md §4).
//!
//! The real data is a gyrokinetic particle distribution: at each of 16 395
//! mesh nodes on each of 8 toroidal cross-sections, a 39x39 2-D velocity
//! histogram (`v_parallel` x `v_perp`). Physically these are near-
//! bi-Maxwellian with temperature/flow varying smoothly over the mesh,
//! and the 8 toroidal planes are near-copies (the paper aggregates the 8
//! histograms at one node into a hyper-block precisely because of that).
//!
//! We generate anisotropic Gaussians whose moments (density, parallel
//! flow, T_par, T_perp) vary smoothly with node index, identical across
//! planes up to a small phase perturbation + noise.

use crate::tensor::Tensor;
use crate::util::parallel::par_map;
use crate::util::rng::Rng;

/// Generate `[planes, nodes, vx, vy]`.
pub fn generate_xgc(dims: &[usize], seed: u64) -> Tensor {
    assert_eq!(dims.len(), 4, "xgc dims are [planes, nodes, vx, vy]");
    let (planes, nodes, nvx, nvy) = (dims[0], dims[1], dims[2], dims[3]);
    let tau = std::f64::consts::TAU;

    // smooth node profiles via a few Fourier components over node index
    let mut rng = Rng::new(seed);
    let comps: Vec<(f64, f64, f64)> = (0..5)
        .map(|i| (rng.range(0.5, 3.0) * (i + 1) as f64, rng.range(0.0, tau), rng.uniform()))
        .collect();
    let profile = |x: f64, which: usize| -> f64 {
        let mut v = 0.0;
        for (j, &(k, ph, a)) in comps.iter().enumerate() {
            v += a * ((k * x + ph + which as f64 * 1.7 + j as f64) * tau * 0.2).sin();
        }
        v / comps.len() as f64
    };

    let hist = nvx * nvy;
    let per_plane = nodes * hist;
    let frames: Vec<Vec<f32>> = par_map(planes * nodes, |pn| {
        let plane = pn / nodes;
        let node = pn % nodes;
        let x = node as f64 / nodes.max(2) as f64;
        // plane-to-plane perturbation is small (strong toroidal correlation)
        let eps = 0.015 * plane as f64;
        let density = 1.0 + 0.5 * profile(x, 0) + 0.02 * (plane as f64 * 2.1).sin();
        let u_par = 0.25 * profile(x + eps, 1); // parallel flow shift
        let t_par = (0.8 + 0.4 * profile(x + eps, 2)).max(0.25);
        let t_perp = (0.8 + 0.4 * profile(x + eps, 3)).max(0.25);
        let mut nrng = Rng::new(seed ^ (pn as u64).wrapping_mul(0x9E37));
        let mut out = vec![0f32; hist];
        for ix in 0..nvx {
            let vx = (ix as f64 / (nvx - 1) as f64 - 0.5) * 6.0; // v_par grid
            for iy in 0..nvy {
                let vy = iy as f64 / (nvy - 1) as f64 * 3.0; // v_perp >= 0
                let e = ((vx - u_par) * (vx - u_par)) / (2.0 * t_par)
                    + (vy * vy) / (2.0 * t_perp);
                // v_perp Jacobian (gyro average) ~ vy
                let f = density * (vy + 0.05) * (-e).exp();
                // particle-count shot noise, kept below the paper's NRMSE
                // targets (DESIGN.md §4)
                let noise = 1.0 + 5e-4 * nrng.normal();
                out[ix * nvy + iy] = (f * noise) as f32;
            }
        }
        out
    });

    let mut data = vec![0f32; planes * per_plane];
    for (pn, h) in frames.into_iter().enumerate() {
        let plane = pn / nodes;
        let node = pn % nodes;
        let off = plane * per_plane + node * hist;
        data[off..off + hist].copy_from_slice(&h);
    }
    Tensor::new(dims.to_vec(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_nonneg() {
        let t = generate_xgc(&[2, 8, 13, 13], 1);
        assert_eq!(t.shape(), &[2, 8, 13, 13]);
        assert!(t.min() >= 0.0, "distribution function is non-negative");
        assert!(t.max() > 0.0);
    }

    #[test]
    fn deterministic() {
        let a = generate_xgc(&[2, 4, 9, 9], 3);
        let b = generate_xgc(&[2, 4, 9, 9], 3);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn planes_strongly_correlated() {
        // the 8 toroidal cross-sections at one node must be near-copies
        let t = generate_xgc(&[4, 6, 15, 15], 5);
        let hist = 15 * 15;
        let per_plane = 6 * hist;
        for node in 0..6 {
            let h0 = &t.data()[node * hist..(node + 1) * hist];
            let h3 = &t.data()[3 * per_plane + node * hist..3 * per_plane + (node + 1) * hist];
            let num: f64 = h0.iter().zip(h3).map(|(&a, &b)| (a as f64) * b as f64).sum();
            let na: f64 = h0.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
            let nb: f64 = h3.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
            let cos = num / (na * nb + 1e-30);
            assert!(cos > 0.98, "node {node}: plane cos-sim {cos}");
        }
    }

    #[test]
    fn histograms_vary_across_nodes() {
        let t = generate_xgc(&[1, 16, 15, 15], 7);
        let hist = 15 * 15;
        let h0 = &t.data()[0..hist];
        let h8 = &t.data()[8 * hist..9 * hist];
        let diff: f64 = h0.iter().zip(h8).map(|(&a, &b)| ((a - b) as f64).abs()).sum();
        assert!(diff > 1e-3, "nodes should differ, diff={diff}");
    }
}
