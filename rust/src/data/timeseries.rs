//! Synthetic smoothly-evolving frame sequences for the temporal stream
//! subsystem (E3SM/XGC-like evolution).
//!
//! A simulation emits one spatial frame per timestep; consecutive frames
//! differ by slow dynamics (traveling synoptic waves, drifting large
//! scale modes), which is exactly the redundancy residual coding
//! exploits. Each frame here is **closed-form in `t`** — no recurrent
//! state — so `frame_at(dims, seed, t)` is identical whether frames are
//! generated in one run or across separate incremental-ingest
//! invocations (the CLI `stream append` relies on this determinism).
//!
//! The recipe, generic over frame rank:
//! * traveling waves: `amp · sin(2π(k·x) + φ − ω t)` with slow per-step
//!   phase speeds — the temporally-correlated bulk of the signal;
//! * slow scalar modes `sin(2π t / P + ψ)` gating fixed Gaussian bumps —
//!   large-scale drift with periods of tens of steps;
//! * a *static* fine-grained texture — spatial detail the codec must
//!   still code in keyframes, but which cancels exactly in residuals.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

struct Wave {
    k: Vec<f64>,
    omega: f64,
    amp: f64,
    phase: f64,
}

struct Mode {
    center: Vec<f64>,
    width: f64,
    amp: f64,
    period: f64,
    phase: f64,
}

struct Texture {
    k: Vec<f64>,
    amp: f64,
    phase: f64,
}

/// The deterministic field parameters for one `(dims, seed)` pair.
struct Series {
    dims: Vec<usize>,
    waves: Vec<Wave>,
    modes: Vec<Mode>,
    texture: Vec<Texture>,
}

impl Series {
    fn new(dims: &[usize], seed: u64) -> Self {
        assert!(!dims.is_empty(), "frame dims must be non-empty");
        let rank = dims.len();
        let mut rng = Rng::new(seed ^ 0x7153_57AE);
        let waves = (0..6)
            .map(|i| Wave {
                k: (0..rank).map(|_| (1 + rng.below(3)) as f64).collect(),
                // slow eastward drift: ~1% of a cycle per step
                omega: std::f64::consts::TAU * rng.range(0.003, 0.012),
                amp: 1.2 / (1.0 + i as f64 * 0.6),
                phase: rng.range(0.0, std::f64::consts::TAU),
            })
            .collect();
        let modes = (0..3)
            .map(|_| Mode {
                center: (0..rank).map(|_| rng.uniform()).collect(),
                width: rng.range(0.12, 0.3),
                amp: rng.range(0.4, 1.0),
                period: rng.range(60.0, 150.0),
                phase: rng.range(0.0, std::f64::consts::TAU),
            })
            .collect();
        let texture = (0..4)
            .map(|_| Texture {
                k: (0..rank).map(|_| (4 + rng.below(5)) as f64).collect(),
                amp: rng.range(0.01, 0.04),
                phase: rng.range(0.0, std::f64::consts::TAU),
            })
            .collect();
        Self { dims: dims.to_vec(), waves, modes, texture }
    }

    fn frame(&self, t: usize) -> Tensor {
        let tau = std::f64::consts::TAU;
        let tt = t as f64;
        let n: usize = self.dims.iter().product();
        let rank = self.dims.len();
        // slow mode gates are per-frame scalars
        let gates: Vec<f64> = self
            .modes
            .iter()
            .map(|m| (tau * tt / m.period + m.phase).sin())
            .collect();
        let mut x = vec![0f64; rank];
        let mut idx = vec![0usize; rank];
        let data: Vec<f32> = (0..n)
            .map(|flat| {
                let mut rem = flat;
                for d in (0..rank).rev() {
                    idx[d] = rem % self.dims[d];
                    rem /= self.dims[d];
                    x[d] = idx[d] as f64 / self.dims[d] as f64;
                }
                let mut v = 0.0f64;
                for w in &self.waves {
                    let kx: f64 = w.k.iter().zip(&x).map(|(k, xd)| k * xd).sum();
                    v += w.amp * (tau * kx + w.phase - w.omega * tt).sin();
                }
                for (m, gate) in self.modes.iter().zip(&gates) {
                    let d2: f64 = m
                        .center
                        .iter()
                        .zip(&x)
                        .map(|(c, xd)| {
                            let mut d = (c - xd).abs();
                            d = d.min(1.0 - d); // periodic domain
                            d * d
                        })
                        .sum();
                    v += m.amp * gate * (-d2 / (2.0 * m.width * m.width)).exp();
                }
                for tx in &self.texture {
                    let kx: f64 = tx.k.iter().zip(&x).map(|(k, xd)| k * xd).sum();
                    v += tx.amp * (tau * kx + tx.phase).sin();
                }
                v as f32
            })
            .collect();
        Tensor::new(self.dims.clone(), data)
    }
}

/// The frame at absolute step `t` of the series `(dims, seed)` —
/// closed-form in `t`, so incremental producers regenerate identical
/// frames at any step without replaying history.
pub fn frame_at(dims: &[usize], seed: u64, t: usize) -> Tensor {
    Series::new(dims, seed).frame(t)
}

/// Frames for steps `start..start + steps`.
pub fn generate_frames(dims: &[usize], seed: u64, start: usize, steps: usize) -> Vec<Tensor> {
    let series = Series::new(dims, seed);
    (start..start + steps).map(|t| series.frame(t)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_matches_batch_generation() {
        let dims = [12, 16];
        let frames = generate_frames(&dims, 7, 3, 4);
        assert_eq!(frames.len(), 4);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.shape(), &dims);
            assert_eq!(f.data(), frame_at(&dims, 7, 3 + i).data(), "step {}", 3 + i);
            assert!(f.data().iter().all(|v| v.is_finite()));
            assert!(f.range() > 0.0);
        }
    }

    #[test]
    fn consecutive_frames_are_strongly_correlated() {
        // the temporal-redundancy premise: |f(t+1) - f(t)| is a small
        // fraction of the field range, while distant frames differ a lot
        let dims = [24, 24];
        let f = generate_frames(&dims, 11, 0, 40);
        let mean_abs = |a: &Tensor, b: &Tensor| -> f64 {
            a.data()
                .iter()
                .zip(b.data())
                .map(|(&x, &y)| (x - y).abs() as f64)
                .sum::<f64>()
                / a.len() as f64
        };
        let adjacent = mean_abs(&f[0], &f[1]);
        let distant = mean_abs(&f[0], &f[30]);
        assert!(
            adjacent < 0.12 * f[0].range() as f64,
            "adjacent delta {adjacent} vs range {}",
            f[0].range()
        );
        assert!(adjacent * 3.0 < distant, "adjacent {adjacent} vs distant {distant}");
    }

    #[test]
    fn generic_over_rank() {
        for dims in [vec![32], vec![8, 8, 6], vec![4, 5, 6, 3]] {
            let a = frame_at(&dims, 3, 10);
            assert_eq!(a.shape(), &dims[..]);
            let b = frame_at(&dims, 3, 10);
            assert_eq!(a.data(), b.data(), "deterministic");
        }
    }
}
