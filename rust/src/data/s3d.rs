//! Synthetic S3D surrogate (DESIGN.md §4).
//!
//! The real S3D HCCI dataset is a 58-species turbulent-combustion DNS
//! (`[species, t, x, y]`). The property the paper's method exploits —
//! and [13] of the paper documents — is that the species are strongly
//! correlated: they evolve on a low-dimensional manifold of reaction
//! modes. We reproduce exactly that: a handful of latent spatiotemporal
//! "reaction modes" (traveling ignition fronts, advected kernels, slow
//! background drift) mixed into the species via a fixed well-conditioned
//! mixing matrix, plus small per-species noise.

use crate::tensor::Tensor;
use crate::util::parallel::par_map;
use crate::util::rng::Rng;

/// Number of latent reaction modes shared across species.
const MODES: usize = 6;

/// Generate `[species, t, x, y]`.
pub fn generate_s3d(dims: &[usize], seed: u64) -> Tensor {
    assert_eq!(dims.len(), 4, "s3d dims are [species, t, x, y]");
    let (s, t, nx, ny) = (dims[0], dims[1], dims[2], dims[3]);
    let mut rng = Rng::new(seed);

    // mode parameters: ignition kernels + fronts
    struct Mode {
        cx: f64,
        cy: f64,
        vx: f64,
        vy: f64,
        width: f64,
        tempo: f64, // ignition growth rate
        phase: f64,
        kind: usize,
    }
    let modes: Vec<Mode> = (0..MODES)
        .map(|m| Mode {
            cx: rng.uniform(),
            cy: rng.uniform(),
            vx: rng.range(-0.2, 0.2),
            vy: rng.range(-0.2, 0.2),
            width: rng.range(0.05, 0.25),
            tempo: rng.range(1.0, 4.0),
            phase: rng.range(0.0, std::f64::consts::TAU),
            kind: m % 3,
        })
        .collect();

    // species mixing matrix: each species = combination of modes, with
    // decaying weights so leading modes dominate (low-rank structure)
    let mix: Vec<f64> = {
        let mut mrng = rng.fork(1);
        (0..s * MODES)
            .map(|i| {
                let m = i % MODES;
                mrng.normal() / (1.0 + m as f64)
            })
            .collect()
    };
    // DNS fields are smooth; the effective noise floor of the real data
    // is far below the paper's NRMSE targets (1e-4..1e-3). Keep ours at
    // ~2e-4 of the signal scale so those targets measure structure, not
    // incompressible noise (DESIGN.md §4).
    let noise_scale = 2e-4;

    // evaluate mode fields per timestep (parallel over t)
    let plane = nx * ny;
    let fields: Vec<Vec<f64>> = par_map(t, |ti| {
        let tt = ti as f64 / t.max(2) as f64;
        let mut field = vec![0.0f64; MODES * plane];
        for (mi, md) in modes.iter().enumerate() {
            let cx = md.cx + md.vx * tt;
            let cy = md.cy + md.vy * tt;
            let amp = match md.kind {
                // ignition kernel: sigmoidal growth in time
                0 => 1.0 / (1.0 + (-md.tempo * (tt - 0.4) * 10.0).exp()),
                // oscillating mode
                1 => (md.tempo * tt * std::f64::consts::TAU + md.phase).sin(),
                // slow drift
                _ => 0.5 + 0.5 * tt,
            };
            for xi in 0..nx {
                let fx = xi as f64 / nx as f64;
                for yi in 0..ny {
                    let fy = yi as f64 / ny as f64;
                    let d2 = (fx - cx).powi(2) + (fy - cy).powi(2);
                    let v = match md.kind {
                        // radial front: sharp sigmoid on distance
                        0 => amp / (1.0 + ((d2.sqrt() - 0.25 * amp) / md.width * 8.0).exp()),
                        // smooth traveling wave
                        1 => amp
                            * ((fx * 3.0 + fy * 2.0) * std::f64::consts::TAU
                                + md.phase
                                + md.tempo * tt * 4.0)
                                .sin()
                            * (-d2 / (2.0 * md.width * md.width)).exp(),
                        // gaussian blob
                        _ => amp * (-d2 / (2.0 * md.width * md.width)).exp(),
                    };
                    field[mi * plane + xi * ny + yi] = v;
                }
            }
        }
        field
    });

    // species = mix · modes + noise  (parallel over species)
    let data_per_species: Vec<Vec<f32>> = par_map(s, |si| {
        let mut srng = Rng::new(seed ^ 0xA5A5_0000 ^ si as u64);
        let weights = &mix[si * MODES..(si + 1) * MODES];
        let mut out = vec![0f32; t * plane];
        for ti in 0..t {
            let field = &fields[ti];
            for p in 0..plane {
                let mut v = 0.0;
                for (mi, &w) in weights.iter().enumerate() {
                    v += w * field[mi * plane + p];
                }
                out[ti * plane + p] = (v + noise_scale * srng.normal()) as f32;
            }
        }
        out
    });

    let mut data = Vec::with_capacity(s * t * plane);
    for sp in data_per_species {
        data.extend(sp);
    }
    Tensor::new(dims.to_vec(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_determinism() {
        let a = generate_s3d(&[4, 6, 16, 16], 1);
        assert_eq!(a.shape(), &[4, 6, 16, 16]);
        let b = generate_s3d(&[4, 6, 16, 16], 1);
        assert_eq!(a.data(), b.data());
        let c = generate_s3d(&[4, 6, 16, 16], 2);
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn species_are_strongly_correlated() {
        // the key structural property: pairwise |corr| between species
        // should be high for several pairs (shared modes dominate noise)
        let t = generate_s3d(&[8, 4, 24, 24], 3);
        let n = 4 * 24 * 24;
        let series: Vec<&[f32]> = (0..8).map(|s| &t.data()[s * n..(s + 1) * n]).collect();
        let corr = |a: &[f32], b: &[f32]| {
            let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
            let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
            let mut num = 0.0;
            let mut da = 0.0;
            let mut db = 0.0;
            for i in 0..n {
                let xa = a[i] as f64 - ma;
                let xb = b[i] as f64 - mb;
                num += xa * xb;
                da += xa * xa;
                db += xb * xb;
            }
            num / (da.sqrt() * db.sqrt() + 1e-30)
        };
        let mut high = 0;
        for i in 0..8 {
            for j in (i + 1)..8 {
                if corr(series[i], series[j]).abs() > 0.5 {
                    high += 1;
                }
            }
        }
        assert!(high >= 5, "only {high} strongly-correlated species pairs");
    }

    #[test]
    fn temporally_smooth() {
        // consecutive timesteps should be much closer than distant ones
        let t = generate_s3d(&[2, 8, 16, 16], 5);
        let plane = 16 * 16;
        let frame = |s: usize, ti: usize| {
            &t.data()[s * 8 * plane + ti * plane..s * 8 * plane + (ti + 1) * plane]
        };
        let dist = |a: &[f32], b: &[f32]| {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) * (x - y)) as f64)
                .sum::<f64>()
                .sqrt()
        };
        let near = dist(frame(0, 3), frame(0, 4));
        let far = dist(frame(0, 0), frame(0, 7));
        assert!(near < far, "near {near} far {far}");
    }
}
