//! Dataset-level compression engine: multi-field archives + the shared
//! block-parallel executor.
//!
//! The paper's headline result (8× over SZ3) is on the *multi-variable*
//! S3D dataset — 100+ species per grid point — yet a single [`Codec`]
//! call compresses one field into one archive. This module scales the
//! crate from field-level to dataset-level:
//!
//! * [`FieldSet`] — named variables sharing one [`DatasetConfig`]
//!   geometry (dims, blocking, normalization policy), built from the
//!   synthetic S3D/E3SM/XGC loaders ([`FieldSet::generate`]), raw files
//!   ([`FieldSet::from_files`]), or pushed tensors.
//! * [`CodecExt::compress_set`] / [`CodecExt::decompress_set`] — pack
//!   every field of a set into one self-describing **Archive v2**
//!   container: per-field sections (`F000`..), a shared stats dictionary
//!   in the header, and CR accounting that recurses into the per-field
//!   payloads (headers excluded — the paper's accounting). v1
//!   single-field archives remain fully readable: `Archive::from_bytes`
//!   accepts every version and `CodecBuilder::for_archive` restores any.
//!   [`CodecExt::decompress_set_region`] decodes one region of interest
//!   of every field (v3 fields touch only the intersecting blocks).
//! * [`Executor`] — the persistent fork-join worker pool (+ per-thread
//!   [`Scratch`] arenas) behind every block-parallel stage: the SZ3-like
//!   and ZFP-like baselines, the GBAE latent coder, the hier GAE bound
//!   stage (Algorithm 1), the lossless coder's chunk streams, the
//!   streaming coordinator's sink stage, and the temporal stream
//!   writer's per-GOP jobs ([`crate::stream::StreamWriter::append_frames`]
//!   schedules whole keyframe+residual chains as pool work items, with
//!   each step's blocks fanning out inside its job). Work items are
//!   independent and order-preserving, so archives are byte-identical at
//!   every thread count (1 thread ≡ N threads).
//!
//! Thread knobs: CLI `--threads N` > `ATTN_REDUCE_THREADS` >
//! `available_parallelism()` (see [`crate::util::parallel`]).
//!
//! ```ignore
//! use attn_reduce::engine::{CodecExt, FieldSet};
//!
//! let set = FieldSet::generate(DatasetKind::S3d, Scale::Bench, 16);
//! let codec = builder.build(CodecKind::Sz3, DatasetKind::S3d, set.field(0))?;
//! let archive = codec.compress_set(&set, &ErrorBound::Nrmse(1e-3))?; // one v2 container
//! let restored = codec.decompress_set(&archive)?;                    // all fields, in order
//! ```

mod executor;
mod fieldset;

pub use executor::{reuse_f32, reuse_i64, Executor, Scratch};
pub use fieldset::FieldSet;

use crate::codec::{Codec, ErrorBound};
use crate::compressor::Archive;
use crate::config::DatasetConfig;
use crate::util::json::{self, Value};
use crate::Result;
use anyhow::{ensure, Context};

/// Dataset-level extension of the [`Codec`] trait: compress/decompress a
/// whole [`FieldSet`] into/from one Archive v2 container. Blanket-implemented
/// for every codec (including `dyn Codec`), so the single-field API is
/// untouched.
pub trait CodecExt: Codec {
    /// Compress every field of `set` under `bound` into one v2 container.
    /// Fields are processed in order (the PJRT-backed codecs are
    /// single-threaded by construction); each field's *blocks* still fan
    /// out across the [`Executor`]. For `Sync` codecs,
    /// [`compress_set_parallel`] adds field-level parallelism on top.
    fn compress_set(&self, set: &FieldSet, bound: &ErrorBound) -> Result<Archive> {
        ensure!(!set.is_empty(), "cannot compress an empty field set");
        let subs: Vec<Archive> = set
            .iter()
            .map(|(name, field)| {
                self.compress(field, bound)
                    .with_context(|| format!("compressing field {name:?}"))
            })
            .collect::<Result<_>>()?;
        pack_set(self.id(), set, bound, subs)
    }

    /// Restore every field of a v2 container, in recorded order.
    fn decompress_set(&self, archive: &Archive) -> Result<FieldSet> {
        ensure!(
            archive.is_multi_field(),
            "not a multi-field (v2) archive — use Codec::decompress"
        );
        let names = archive.field_names()?;
        let dataset = DatasetConfig::from_json(archive.header.req("dataset")?)?;
        ensure!(
            names.len() == archive.field_count(),
            "v2 header lists {} fields but container has {} sections",
            names.len(),
            archive.field_count()
        );
        let mut set = FieldSet::new(dataset);
        for (i, name) in names.iter().enumerate() {
            let sub = archive.field_archive(i)?;
            let field = self
                .decompress(&sub)
                .with_context(|| format!("decompressing field {name:?}"))?;
            set.push(name.clone(), field)?;
        }
        Ok(set)
    }

    /// Restore only `region` of every field of a v2 container, in
    /// recorded order. Returns `(name, region tensor)` pairs (region
    /// shapes don't match the dataset dims, so this is not a
    /// [`FieldSet`]). Fields stored as v3 archives decode only the
    /// blocks the region intersects; v1 fields fall back to full decode
    /// + crop — the API is uniform across versions.
    fn decompress_set_region(
        &self,
        archive: &Archive,
        region: &crate::data::Region,
    ) -> Result<Vec<(String, crate::tensor::Tensor)>> {
        ensure!(
            archive.is_multi_field(),
            "not a multi-field (v2) archive — use Codec::decompress_region"
        );
        let names = archive.field_names()?;
        ensure!(
            names.len() == archive.field_count(),
            "v2 header lists {} fields but container has {} sections",
            names.len(),
            archive.field_count()
        );
        let mut out = Vec::with_capacity(names.len());
        for (i, name) in names.iter().enumerate() {
            let sub = archive.field_archive(i)?;
            let field = self
                .decompress_region(&sub, region)
                .with_context(|| format!("decompressing region of field {name:?}"))?;
            out.push((name.clone(), field));
        }
        Ok(out)
    }
}

impl<C: Codec + ?Sized> CodecExt for C {}

/// Field-parallel variant of [`CodecExt::compress_set`] for `Sync`
/// codecs (the pure-rust `sz3` / `zfp` baselines): per-field jobs fan
/// out across the [`Executor`], and each field's per-block work runs
/// inline on its worker. Produces a container byte-identical to the
/// serial path.
pub fn compress_set_parallel<C>(
    codec: &C,
    set: &FieldSet,
    bound: &ErrorBound,
) -> Result<Archive>
where
    C: Codec + Sync,
{
    ensure!(!set.is_empty(), "cannot compress an empty field set");
    let subs = Executor::global().try_par_map(set.len(), |i| {
        codec
            .compress(set.field(i), bound)
            .with_context(|| format!("compressing field {:?}", set.names()[i]))
    })?;
    pack_set(codec.id(), set, bound, subs)
}

/// Assemble the v2 container: header (codec id, bound, dataset, field
/// names, shared stats dictionary) + one embedded v1 archive per field.
fn pack_set(
    codec_id: &str,
    set: &FieldSet,
    bound: &ErrorBound,
    subs: Vec<Archive>,
) -> Result<Archive> {
    ensure!(set.len() <= 1000, "v2 containers hold at most 1000 fields");
    ensure!(subs.len() == set.len());
    // shared stats dictionary: one entry per field with the value range
    // (CR denominators, bound derivations) and the normalization stats
    // when the codec recorded them
    let stats: Vec<(String, Value)> = set
        .iter()
        .zip(&subs)
        .map(|((name, field), sub)| {
            let mut entry = vec![
                ("min".to_string(), json::num(field.min() as f64)),
                ("max".to_string(), json::num(field.max() as f64)),
                ("range".to_string(), json::num(field.range() as f64)),
            ];
            if let Some(norm) = sub.header.get("norm") {
                entry.push(("norm".to_string(), norm.clone()));
            }
            (name.to_string(), Value::Obj(entry))
        })
        .collect();
    let header = json::obj(vec![
        ("codec", json::s(codec_id)),
        ("bound", bound.to_json()),
        ("dataset", set.dataset().to_json()),
        (
            "fields",
            Value::Arr(set.names().iter().map(|n| json::s(n.as_str())).collect()),
        ),
        ("stats", Value::Obj(stats)),
    ]);
    let mut archive = Archive::new_v2(header);
    for sub in &subs {
        archive.add_field_archive(sub)?;
    }
    Ok(archive)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Sz3Codec;
    use crate::config::{DatasetKind, Scale};

    #[test]
    fn set_round_trip_preserves_names_and_order() {
        let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 3);
        let codec = Sz3Codec::new(set.dataset().clone());
        let bound = ErrorBound::Nrmse(1e-3);
        let archive = codec.compress_set(&set, &bound).unwrap();
        assert!(archive.is_multi_field());
        assert_eq!(archive.field_count(), 3);
        let back = codec.decompress_set(&archive).unwrap();
        assert_eq!(back.names(), set.names());
        for (i, (_, orig)) in set.iter().enumerate() {
            let e = crate::compressor::nrmse(orig, back.field(i));
            assert!(e <= 1e-3, "field {i}: NRMSE {e}");
        }
    }

    #[test]
    fn parallel_and_serial_set_compression_are_identical() {
        let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 4);
        let codec = Sz3Codec::new(set.dataset().clone());
        let bound = ErrorBound::Nrmse(1e-3);
        let serial = codec.compress_set(&set, &bound).unwrap();
        let parallel = compress_set_parallel(&codec, &set, &bound).unwrap();
        assert_eq!(serial.to_bytes(), parallel.to_bytes());
    }

    #[test]
    fn header_carries_shared_stats_dictionary() {
        let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 2);
        let codec = Sz3Codec::new(set.dataset().clone());
        let archive = codec.compress_set(&set, &ErrorBound::Nrmse(1e-3)).unwrap();
        let stats = archive.header.req("stats").unwrap();
        for name in set.names() {
            let entry = stats.req(name).unwrap();
            assert!(entry.req("range").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn set_region_decode_matches_cropped_full_decode() {
        use crate::data::Region;
        let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 2);
        let codec = Sz3Codec::new(set.dataset().clone());
        let archive = codec.compress_set(&set, &ErrorBound::Nrmse(1e-3)).unwrap();
        let full = codec.decompress_set(&archive).unwrap();
        let region = Region::parse("2:14,8:24,0:16").unwrap();
        let parts = codec.decompress_set_region(&archive, &region).unwrap();
        assert_eq!(parts.len(), 2);
        for (i, (name, t)) in parts.iter().enumerate() {
            assert_eq!(name, &set.names()[i]);
            assert_eq!(t.shape(), &region.shape()[..]);
            assert_eq!(t.data(), region.crop(full.field(i)).unwrap().data());
        }
        // misuse: the set-region API on a single-field archive
        let single = codec
            .compress(set.field(0), &ErrorBound::Nrmse(1e-3))
            .unwrap();
        assert!(codec.decompress_set_region(&single, &region).is_err());
    }

    #[test]
    fn empty_set_and_v1_misuse_are_errors() {
        let set = FieldSet::generate(DatasetKind::E3sm, Scale::Smoke, 1);
        let codec = Sz3Codec::new(set.dataset().clone());
        let empty = FieldSet::new(set.dataset().clone());
        assert!(codec.compress_set(&empty, &ErrorBound::None).is_err());
        let v1 = codec
            .compress(set.field(0), &ErrorBound::Nrmse(1e-3))
            .unwrap();
        assert!(codec.decompress_set(&v1).is_err());
    }
}
