//! [`Executor`] — the shared block-parallel work engine.
//!
//! One persistent pool of OS worker threads serves every data-parallel
//! stage in the crate: the baselines' per-block loops, the GAE bound
//! stage (Algorithm 1), the lossless coder's chunk streams, the
//! streaming coordinator's sink stage, and the engine's per-field jobs.
//! It replaces the previous ad-hoc `std::thread::scope` spawns in
//! `util/parallel`, which paid a thread spawn/join per call and had no
//! buffer reuse.
//!
//! Design:
//!
//! * **Fork-join batches over a persistent pool.** A batch is an index
//!   range `0..n` drained through an atomic counter (work stealing,
//!   order-preserving output). The submitting thread participates, so a
//!   pool of `T` threads yields `T`-way parallelism with `T - 1` workers.
//! * **Per-thread scratch arenas.** Every pool thread owns a
//!   [`Scratch`] (thread-local, reused across batches), so per-block
//!   temporaries (rows, coefficient vectors, transform buffers) stop
//!   hitting the allocator in hot loops.
//! * **Panic propagation.** A panicking work item stops the batch and
//!   the *original payload* is resumed on the submitting thread —
//!   `par_map` used to abort with a misleading `unwrap` on a `None`
//!   slot.
//! * **Deterministic by construction.** Work items are independent and
//!   outputs land in submission order, so results are byte-identical
//!   for 1 thread and N threads. Nested batches run inline on the
//!   already-parallel thread (same structure at every thread count).
//!
//! Thread-count resolution lives in [`crate::util::parallel`]:
//! CLI `--threads` override > `ATTN_REDUCE_THREADS` env > available
//! parallelism, with a thread-local limit for determinism tests.

use std::cell::{Cell, RefCell};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::util::parallel::num_threads;

type Payload = Box<dyn std::any::Any + Send + 'static>;

/// Per-thread reusable buffers. Each pool thread (and the submitting
/// thread) owns one, persistent across batches — hot loops index into
/// cleared-and-resized buffers instead of allocating.
#[derive(Default)]
pub struct Scratch {
    pub f32_a: Vec<f32>,
    pub f32_b: Vec<f32>,
    /// Third f32 buffer for stages that already hold `f32_a`/`f32_b`
    /// (e.g. the sz3 row-base pass while `f32_b` carries the tile).
    pub f32_c: Vec<f32>,
    pub f64_a: Vec<f64>,
    pub i64_a: Vec<i64>,
    pub i32_a: Vec<i32>,
    pub bytes: Vec<u8>,
    /// Entropy-stage decode state (Huffman table/LUT + staging buffers),
    /// reused across per-tile decodes on this thread.
    pub symbols: crate::coder::lossless::SymbolScratch,
}

/// Clear + zero-fill a scratch `f32` buffer to `len`, returning the slice.
pub fn reuse_f32(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(len, 0.0);
    &mut buf[..]
}

/// Clear + zero-fill a scratch `i64` buffer to `len`, returning the slice.
pub fn reuse_i64(buf: &mut Vec<i64>, len: usize) -> &mut [i64] {
    buf.clear();
    buf.resize(len, 0);
    &mut buf[..]
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// RAII guard marking this thread as executing pool work (nested batches
/// run inline — identical structure at every thread count, and no
/// deadlock on the single batch slot).
struct PoolGuard {
    prev: bool,
}

impl PoolGuard {
    fn enter() -> Self {
        let prev = IN_POOL.with(|f| f.replace(true));
        Self { prev }
    }
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL.with(|f| f.set(prev));
    }
}

/// Type-erased handle to the in-flight batch (fn pointer + pointer to a
/// stack-allocated `BatchData` in the submitter's frame). Sound because
/// the submitter blocks until every worker has finished the batch.
#[derive(Clone, Copy)]
struct JobSlot {
    run: unsafe fn(*const (), usize),
    data: *const (),
}

// SAFETY: the pointers are only dereferenced while the submitting
// thread keeps the batch alive (it waits for `remaining == 0`).
unsafe impl Send for JobSlot {}

struct State {
    epoch: u64,
    job: Option<JobSlot>,
    /// Workers that have not yet finished (or skipped) the current batch.
    remaining: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// Submitters wait here for batch completion / a free job slot.
    done_cv: Condvar,
}

/// Thread-local *forcing* state captured on the submitting thread and
/// installed on every batch participant: the symbol-container and
/// tile-codec overrides (`with_symbol_mode` / `with_tile_codec`) are
/// thread-locals, and pool workers do not inherit the submitter's —
/// without propagation a force wrapped around a parallel compress would
/// silently apply only to the tiles the submitting thread happens to
/// drain, making forced output thread-count-dependent. The
/// observability span context rides along for the same reason: spans
/// opened by work items nest under the submitting request/command in
/// `--trace` output instead of floating parentless.
#[derive(Clone, Copy)]
struct ForceContext {
    symbol_mode: Option<crate::coder::lossless::SymbolMode>,
    tile_codec: Option<crate::codec::TileCodec>,
    obs_span: crate::obs::SpanContext,
}

impl ForceContext {
    fn capture() -> Self {
        Self {
            symbol_mode: crate::coder::lossless::forced_symbol_mode(),
            tile_codec: crate::codec::forced_tile_codec(),
            obs_span: crate::obs::SpanContext::capture(),
        }
    }

    fn set(ctx: Self) {
        crate::coder::lossless::set_forced_symbol_mode(ctx.symbol_mode);
        crate::codec::set_forced_tile_codec(ctx.tile_codec);
        ctx.obs_span.set();
    }

    /// Install this context on the current thread, restoring the
    /// previous state when the guard drops (panic-safe: a panicking work
    /// item must not leak a force onto a pool worker).
    fn install(self) -> ForceGuard {
        let prev = ForceContext::capture();
        ForceContext::set(self);
        ForceGuard { prev }
    }
}

struct ForceGuard {
    prev: ForceContext,
}

impl Drop for ForceGuard {
    fn drop(&mut self) {
        ForceContext::set(self.prev);
    }
}

struct BatchData<'a, T, F> {
    next: &'a AtomicUsize,
    n: usize,
    /// Total participants (submitter + workers `0..limit-1`).
    limit: usize,
    f: &'a F,
    out: *mut Option<T>,
    panic: &'a Mutex<Option<Payload>>,
    /// Submitter's forcing context, installed on every participant.
    force: ForceContext,
}

fn drain<T, F>(b: &BatchData<'_, T, F>)
where
    T: Send,
    F: Fn(usize, &mut Scratch) -> T + Sync,
{
    let _force = b.force.install();
    SCRATCH.with(|cell| {
        let mut borrow = cell.borrow_mut();
        let scratch: &mut Scratch = &mut borrow;
        loop {
            let i = b.next.fetch_add(1, Ordering::Relaxed);
            if i >= b.n {
                break;
            }
            match catch_unwind(AssertUnwindSafe(|| (b.f)(i, &mut *scratch))) {
                // SAFETY: index `i` is claimed exactly once via the
                // atomic counter; the output vec outlives the batch.
                Ok(v) => unsafe { *b.out.add(i) = Some(v) },
                Err(payload) => {
                    let mut slot = b.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    b.next.store(b.n, Ordering::Relaxed); // stop the batch
                    break;
                }
            }
        }
    });
}

unsafe fn run_batch<T, F>(data: *const (), worker_id: usize)
where
    T: Send,
    F: Fn(usize, &mut Scratch) -> T + Sync,
{
    let b = &*(data as *const BatchData<'_, T, F>);
    // the submitter occupies one participant slot; workers beyond the
    // batch's effective thread count just report done
    if worker_id + 1 < b.limit {
        drain(b);
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(job) = st.job {
                        seen = st.epoch;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        {
            let _guard = PoolGuard::enter();
            // SAFETY: the submitter keeps the batch alive until we
            // decrement `remaining` below.
            unsafe { (job.run)(job.data, id) };
        }
        let mut st = shared.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Persistent fork-join worker pool with per-thread scratch arenas.
pub struct Executor {
    shared: &'static Shared,
    workers: usize,
    /// Join handles, present only for non-global executors (tests).
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Pool sized for `threads`-way parallelism (the submitting thread
    /// counts as one; `threads - 1` workers are spawned).
    pub fn new(threads: usize) -> Self {
        let workers = threads.max(1) - 1;
        // leaked so worker threads can hold a 'static reference; an
        // Executor is either the process-wide global or a short-lived
        // test fixture, so the leak is bounded and intentional
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                remaining: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }));
        let handles = (0..workers)
            .map(|id| {
                std::thread::Builder::new()
                    .name(format!("attn-exec-{id}"))
                    .spawn(move || worker_loop(shared, id))
                    .expect("spawn executor worker")
            })
            .collect();
        Self { shared, workers, handles }
    }

    /// The process-wide pool, sized once from the thread policy at first
    /// use. Capacity is capped at `max(available_parallelism, 64)` so an
    /// absurd `--threads`/`ATTN_REDUCE_THREADS` value cannot spawn
    /// unbounded OS threads; requests above the cap simply use every
    /// pool thread (per-batch `eff` is re-derived from the policy).
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            Executor::new(num_threads().clamp(avail, avail.max(64)))
        })
    }

    /// Maximum parallelism this pool can deliver (workers + submitter).
    pub fn capacity(&self) -> usize {
        self.workers + 1
    }

    /// Parallel map preserving order: `out[i] = f(i, scratch)`. Panics in
    /// `f` stop the batch and are re-raised with the original payload.
    pub fn par_map_scratch<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut Scratch) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        // nested batch (already on a pool thread): run inline with a
        // fresh scratch — the thread-local one is borrowed by the outer
        // batch's drain
        if IN_POOL.with(|flag| flag.get()) {
            let mut scratch = Scratch::default();
            return (0..n).map(|i| f(i, &mut scratch)).collect();
        }
        let eff = num_threads().min(n).min(self.capacity());
        if eff <= 1 {
            let _guard = PoolGuard::enter();
            return SCRATCH.with(|cell| {
                let mut borrow = cell.borrow_mut();
                let scratch: &mut Scratch = &mut borrow;
                (0..n).map(|i| f(i, &mut *scratch)).collect()
            });
        }

        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let next = AtomicUsize::new(0);
        let panic_slot: Mutex<Option<Payload>> = Mutex::new(None);
        let batch = BatchData {
            next: &next,
            n,
            limit: eff,
            f: &f,
            out: out.as_mut_ptr(),
            panic: &panic_slot,
            // the inline paths above run on the submitting thread and
            // inherit its thread-locals for free; pooled workers get the
            // same view via this captured context
            force: ForceContext::capture(),
        };

        // install the batch (one in flight at a time; concurrent
        // submitters queue on the slot)
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.job.is_some() {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = Some(JobSlot {
                run: run_batch::<T, F>,
                data: &batch as *const _ as *const (),
            });
            st.epoch = st.epoch.wrapping_add(1);
            st.remaining = self.workers;
            self.shared.work_cv.notify_all();
        }

        // the submitter is participant number `limit - 1`
        {
            let _guard = PoolGuard::enter();
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| drain(&batch))) {
                let mut slot = panic_slot.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
                next.store(n, Ordering::Relaxed);
            }
        }

        // wait for every worker to finish (or skip) the batch, then free
        // the slot for queued submitters
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            self.shared.done_cv.notify_all();
        }

        if let Some(payload) = panic_slot.into_inner().unwrap() {
            resume_unwind(payload);
        }
        out.into_iter()
            .map(|slot| slot.expect("executor: unfilled output slot"))
            .collect()
    }

    /// [`Self::par_map_scratch`] without the scratch argument.
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.par_map_scratch(n, |i, _| f(i))
    }

    /// Fallible parallel map: all items run (no short-circuit), then the
    /// first error by index is returned.
    pub fn try_par_map<T, F>(&self, n: usize, f: F) -> crate::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> crate::Result<T> + Sync,
    {
        let results = self.par_map(n, f);
        results.into_iter().collect()
    }

    /// [`Self::try_par_map`] with the per-thread scratch arena (the tile
    /// encode/decode hot path).
    pub fn try_par_map_scratch<T, F>(&self, n: usize, f: F) -> crate::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, &mut Scratch) -> crate::Result<T> + Sync,
    {
        let results = self.par_map_scratch(n, f);
        results.into_iter().collect()
    }

    /// Panic-isolated parallel map: each item runs under `catch_unwind`,
    /// so one panicking item yields `Err(message)` in its slot instead
    /// of tearing down the whole batch. Built for request-pool callers
    /// (the serving layer) where work items are independent client
    /// connections and the process must outlive any of them.
    pub fn par_map_isolated<T, F>(&self, n: usize, f: F) -> Vec<std::result::Result<T, String>>
    where
        T: Send,
        F: Fn(usize, &mut Scratch) -> T + Sync,
    {
        self.par_map_scratch(n, move |i, scratch| {
            catch_unwind(AssertUnwindSafe(|| f(i, scratch))).map_err(|payload| {
                payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "non-string panic payload".to_string())
            })
        })
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_reuses_pool() {
        let ex = Executor::new(4);
        for round in 0..5 {
            let out = ex.par_map(257, |i| i * 2 + round);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 2 + round);
            }
        }
    }

    #[test]
    fn scratch_buffers_are_reused() {
        let ex = Executor::new(3);
        // first round grows the arena; later rounds must see capacity
        let caps: Vec<usize> = ex.par_map_scratch(64, |_, s| {
            reuse_f32(&mut s.f32_a, 4096);
            s.f32_a.capacity()
        });
        assert!(caps.iter().all(|&c| c >= 4096));
        let again = ex.par_map_scratch(64, |_, s| s.f32_a.capacity());
        // at least the submitting thread's arena persists across batches
        assert!(again.iter().any(|&c| c >= 4096));
    }

    #[test]
    fn propagates_original_panic_payload() {
        let ex = Executor::new(4);
        let err = catch_unwind(AssertUnwindSafe(|| {
            ex.par_map(100, |i| {
                if i == 37 {
                    panic!("work item {i} exploded");
                }
                i
            })
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("work item 37 exploded"), "payload lost: {msg:?}");
        // pool still usable after a panicked batch
        assert_eq!(ex.par_map(8, |i| i), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn nested_batches_run_inline() {
        let ex = Executor::new(4);
        let out = ex.par_map(16, |i| {
            // nested call on a pool thread: must not deadlock
            let inner = Executor::global().par_map(8, move |j| i * 8 + j);
            inner.iter().sum::<usize>()
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (0..8).map(|j| i * 8 + j).sum::<usize>());
        }
    }

    #[test]
    fn try_par_map_reports_first_error_by_index() {
        let ex = Executor::new(4);
        let r = ex.try_par_map(50, |i| {
            if i == 20 || i == 31 {
                anyhow::bail!("item {i} failed")
            }
            Ok(i)
        });
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("item 20"), "{msg}");
        let ok = ex
            .try_par_map(4, |i| -> crate::Result<usize> { Ok(i * 2) })
            .unwrap();
        assert_eq!(ok, vec![0, 2, 4, 6]);
    }

    #[test]
    fn isolated_map_contains_panics_to_their_slot() {
        let ex = Executor::new(4);
        let out = ex.par_map_isolated(40, |i, _| {
            if i == 13 {
                panic!("connection {i} blew up");
            }
            i * 3
        });
        for (i, r) in out.iter().enumerate() {
            match r {
                Ok(v) => assert_eq!(*v, i * 3),
                Err(msg) => {
                    assert_eq!(i, 13, "only item 13 panics");
                    assert!(msg.contains("connection 13 blew up"), "{msg}");
                }
            }
        }
        // the batch itself completed: every non-panicking slot is Ok
        assert_eq!(out.iter().filter(|r| r.is_err()).count(), 1);
        // pool still usable afterwards
        assert_eq!(ex.par_map(4, |i| i), vec![0, 1, 2, 3]);
    }

    #[test]
    fn empty_and_single() {
        let ex = Executor::new(2);
        assert!(ex.par_map(0, |i| i).is_empty());
        assert_eq!(ex.par_map(1, |i| i + 9), vec![9]);
    }
}
