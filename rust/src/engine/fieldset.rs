//! [`FieldSet`] — a named collection of variables over one dataset
//! geometry.
//!
//! The paper's headline S3D result is a *multi-variable* dataset (100+
//! species per grid point); E3SM restart files likewise carry many
//! climate variables on the same grid. A `FieldSet` models that: every
//! field shares the [`DatasetConfig`] dims / blocking / normalization
//! policy, and the engine compresses the whole set into one Archive v2
//! container ([`super::CodecExt::compress_set`]).

use crate::config::{dataset_preset, DatasetConfig, DatasetKind, Scale};
use crate::data;
use crate::tensor::Tensor;
use crate::Result;
use anyhow::{bail, ensure};

/// Named fields sharing one dataset geometry.
#[derive(Debug, Clone)]
pub struct FieldSet {
    dataset: DatasetConfig,
    names: Vec<String>,
    fields: Vec<Tensor>,
}

impl FieldSet {
    /// An empty set over `dataset`'s geometry.
    pub fn new(dataset: DatasetConfig) -> Self {
        Self { dataset, names: Vec::new(), fields: Vec::new() }
    }

    /// Add a field. Its shape must match the dataset dims, and names must
    /// be unique within the set and filesystem-safe: archive headers are
    /// untrusted input, and v2 decompression splices field names into
    /// output paths, so path separators and control bytes are rejected
    /// here (the one choke point both compress and decompress go through).
    pub fn push(&mut self, name: impl Into<String>, field: Tensor) -> Result<()> {
        let name = name.into();
        ensure!(
            !name.is_empty() && name.len() <= 128,
            "field name must be 1..=128 bytes"
        );
        ensure!(
            !name
                .chars()
                .any(|c| c == '/' || c == '\\' || c == ':' || c.is_control()),
            "field name {name:?} contains path separators or control characters"
        );
        ensure!(
            field.shape() == &self.dataset.dims[..],
            "field {name:?} shape {:?} != dataset dims {:?}",
            field.shape(),
            self.dataset.dims
        );
        if self.names.iter().any(|n| *n == name) {
            bail!("duplicate field name {name:?} in set");
        }
        self.names.push(name);
        self.fields.push(field);
        Ok(())
    }

    /// Synthesize a multi-variable set from a dataset preset: `n_vars`
    /// fields named `var00..`, each generated with a distinct seed so the
    /// variables are decorrelated (like distinct species / restart
    /// variables).
    pub fn generate(kind: DatasetKind, scale: Scale, n_vars: usize) -> Self {
        let base = dataset_preset(kind, scale);
        let mut set = Self::new(base.clone());
        for v in 0..n_vars {
            let mut cfg = base.clone();
            cfg.seed = base.seed.wrapping_add(1000 * (v as u64 + 1));
            let field = data::generate(&cfg);
            set.push(format!("var{v:02}"), field).expect("generated field fits preset");
        }
        set
    }

    /// Load fields from raw `.f32` files; each file name (stem) becomes
    /// the field name.
    pub fn from_files<P: AsRef<std::path::Path>>(
        dataset: DatasetConfig,
        paths: &[P],
    ) -> Result<Self> {
        let mut set = Self::new(dataset);
        for p in paths {
            let p = p.as_ref();
            let name = p
                .file_stem()
                .and_then(|s| s.to_str())
                .map(String::from)
                .unwrap_or_else(|| format!("field{:02}", set.len()));
            let field = data::read_f32_file(p, set.dataset.dims.clone())?;
            set.push(name, field)?;
        }
        Ok(set)
    }

    pub fn dataset(&self) -> &DatasetConfig {
        &self.dataset
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn field(&self, i: usize) -> &Tensor {
        &self.fields[i]
    }

    pub fn by_name(&self, name: &str) -> Option<&Tensor> {
        self.names.iter().position(|n| n == name).map(|i| &self.fields[i])
    }

    /// `(name, field)` pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(|n| n.as_str()).zip(self.fields.iter())
    }

    /// Total points across all fields (the CR numerator for a set).
    pub fn total_points(&self) -> usize {
        self.dataset.total_points() * self.fields.len()
    }

    /// Raw f32 bytes across all fields.
    pub fn raw_bytes(&self) -> usize {
        self.total_points() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_makes_distinct_named_variables() {
        let set = FieldSet::generate(DatasetKind::S3d, Scale::Smoke, 3);
        assert_eq!(set.len(), 3);
        assert_eq!(set.names(), &["var00", "var01", "var02"]);
        assert_eq!(set.field(0).shape(), &set.dataset().dims[..]);
        assert_ne!(set.field(0).data(), set.field(1).data());
        assert_eq!(set.total_points(), set.dataset().total_points() * 3);
        assert!(set.by_name("var01").is_some());
        assert!(set.by_name("nope").is_none());
    }

    #[test]
    fn push_validates_shape_and_name() {
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let good = data::generate(&cfg);
        let mut set = FieldSet::new(cfg);
        set.push("t", good.clone()).unwrap();
        assert!(set.push("t", good.clone()).is_err(), "duplicate name");
        let bad = Tensor::zeros(vec![2, 2]);
        assert!(set.push("u", bad).is_err(), "shape mismatch");
        assert_eq!(set.len(), 1);
    }

    #[test]
    fn push_rejects_path_traversal_names() {
        // v2 headers are untrusted; names are spliced into output paths
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let good = data::generate(&cfg);
        let mut set = FieldSet::new(cfg);
        for bad in ["../../escape", "a/b", "a\\b", "C:evil", "", "x\0y"] {
            assert!(set.push(bad, good.clone()).is_err(), "{bad:?} accepted");
        }
        set.push("ok_name-1.2", good).unwrap();
    }

    #[test]
    fn file_round_trip() {
        let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
        let dir = std::env::temp_dir().join("attn_reduce_fieldset_test");
        std::fs::create_dir_all(&dir).unwrap();
        let a = data::generate(&cfg);
        let pa = dir.join("temp.f32");
        data::write_f32_file(&pa, &a).unwrap();
        let set = FieldSet::from_files(cfg, &[&pa]).unwrap();
        assert_eq!(set.names(), &["temp"]);
        assert_eq!(set.field(0).data(), a.data());
        std::fs::remove_dir_all(&dir).ok();
    }
}
