//! Bounded LRU cache for the serving layer: open [`StreamReader`]s /
//! parsed [`Archive`]s (keyed by file path) and decoded keyframe
//! regions (keyed by `(path, keyframe step, region class)`).
//!
//! Admission and eviction are driven by byte accounting — an entry's
//! cost is what it pins in memory (file bytes for readers/archives,
//! `4 * points` for decoded frames), and each entry records the payload
//! bytes a hit *saves* (from `StreamReader::region_cost` for keyframes),
//! so the `/v1/stats` route and `BENCH_serve.json` can report exactly
//! how many compressed bytes the cache kept off the decode path.
//! Everything lives behind one `Mutex`: entries are `Arc`s, so the lock
//! covers only map bookkeeping, never decode work.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::compressor::Archive;
use crate::stream::StreamReader;
use crate::tensor::Tensor;

/// Identity of a file's *contents* at lookup time: `(len, mtime)` from
/// a fresh stat. Baking the stamp into every cache key makes an
/// overwritten or externally-replaced file an automatic miss — stale
/// readers/archives/keyframes can never be served, even when the writer
/// bypassed [`LruCache::invalidate_file`] (e.g. an out-of-process
/// `cli compress` into the serve root).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct FileStamp {
    pub len: u64,
    /// Modification time as `(secs, nanos)` since the UNIX epoch
    /// (pre-epoch or unsupported mtimes collapse to `(0, 0)`).
    pub mtime: (u64, u32),
}

impl FileStamp {
    pub fn of(path: &Path) -> std::io::Result<Self> {
        let m = std::fs::metadata(path)?;
        let mtime = match m.modified().map(|t| t.duration_since(std::time::UNIX_EPOCH)) {
            Ok(Ok(d)) => (d.as_secs(), d.subsec_nanos()),
            _ => (0, 0),
        };
        Ok(Self { len: m.len(), mtime })
    }
}

/// What a cached entry is keyed by.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CacheKey {
    /// A parsed on-disk file (stream reader or archive), pinned to the
    /// content stamp observed when it was loaded.
    File(PathBuf, FileStamp),
    /// A decoded keyframe region: `(file, stamp, keyframe step, region
    /// class)` where the class is the canonical `lo:hi,...` spelling (a
    /// full frame and an explicit full region share one entry).
    Keyframe(PathBuf, FileStamp, usize, String),
}

impl CacheKey {
    fn path(&self) -> &Path {
        match self {
            CacheKey::File(p, _) => p,
            CacheKey::Keyframe(p, _, _, _) => p,
        }
    }
}

/// Shared handles to cached objects (cheap to clone out of the lock).
#[derive(Clone)]
pub enum CacheValue {
    Reader(Arc<StreamReader>),
    Archive(Arc<Archive>),
    Frame(Arc<Tensor>),
}

struct Slot {
    value: CacheValue,
    /// Resident bytes this entry pins.
    cost: usize,
    /// Payload bytes one hit on this entry avoids decoding/reading.
    saved: usize,
    /// LRU clock tick of the last touch.
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<CacheKey, Slot>,
    bytes: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    insertions: u64,
    /// Inserts refused because a single entry exceeded the capacity.
    refusals: u64,
    /// Entries dropped by `invalidate_file` (file overwrites).
    invalidations: u64,
    /// Cumulative `saved` over all hits.
    bytes_saved: u64,
}

/// Counter snapshot for `/v1/stats`, `/v1/metrics` and the bench report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheCounters {
    pub entries: usize,
    pub bytes: usize,
    pub capacity_bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
    pub refusals: u64,
    pub invalidations: u64,
    pub bytes_saved: u64,
}

/// Byte-bounded LRU over [`CacheKey`] → [`CacheValue`].
pub struct LruCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl LruCache {
    pub fn new(capacity_bytes: usize) -> Self {
        Self { capacity: capacity_bytes, inner: Mutex::new(Inner::default()) }
    }

    /// Look up `key`, counting a hit (and its saved bytes) or a miss.
    pub fn get(&self, key: &CacheKey) -> Option<CacheValue> {
        let _span = crate::obs::stages::CACHE_GET.span();
        let mut guard = self.inner.lock().unwrap();
        // reborrow so map access and counter updates split by field
        let inner = &mut *guard;
        inner.tick += 1;
        match inner.map.get_mut(key) {
            Some(slot) => {
                slot.last_used = inner.tick;
                inner.hits += 1;
                inner.bytes_saved += slot.saved as u64;
                Some(slot.value.clone())
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Admit `value` at `cost` resident bytes, evicting least-recently
    /// used entries until it fits. An entry larger than the whole cache
    /// is refused (the request still succeeds, it just isn't cached).
    pub fn insert(&self, key: CacheKey, value: CacheValue, cost: usize, saved: usize) {
        let _span = crate::obs::stages::CACHE_INSERT.span();
        if cost > self.capacity {
            self.inner.lock().unwrap().refusals += 1;
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.cost;
        }
        while inner.bytes + cost > self.capacity {
            let Some(victim) = inner
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let gone = inner.map.remove(&victim).expect("victim present");
            inner.bytes -= gone.cost;
            inner.evictions += 1;
        }
        inner.bytes += cost;
        inner.insertions += 1;
        inner.map.insert(key, Slot { value, cost, saved, last_used: tick });
    }

    /// Drop every entry derived from `path` (the `POST /v1/compress`
    /// overwrite path: a rewritten file invalidates its reader, archive
    /// and keyframes together).
    pub fn invalidate_file(&self, path: &Path) {
        let mut inner = self.inner.lock().unwrap();
        let doomed: Vec<CacheKey> = inner
            .map
            .keys()
            .filter(|k| k.path() == path)
            .cloned()
            .collect();
        for key in doomed {
            let gone = inner.map.remove(&key).expect("doomed key present");
            inner.bytes -= gone.cost;
            inner.invalidations += 1;
        }
    }

    pub fn counters(&self) -> CacheCounters {
        let inner = self.inner.lock().unwrap();
        CacheCounters {
            entries: inner.map.len(),
            bytes: inner.bytes,
            capacity_bytes: self.capacity,
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            insertions: inner.insertions,
            refusals: inner.refusals,
            invalidations: inner.invalidations,
            bytes_saved: inner.bytes_saved,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(points: usize) -> CacheValue {
        CacheValue::Frame(Arc::new(Tensor::new(vec![points], vec![0.0; points])))
    }

    fn key(name: &str, step: usize) -> CacheKey {
        CacheKey::Keyframe(PathBuf::from(name), FileStamp::default(), step, "full".to_string())
    }

    #[test]
    fn hit_miss_and_saved_byte_accounting() {
        let cache = LruCache::new(1000);
        assert!(cache.get(&key("a", 0)).is_none());
        cache.insert(key("a", 0), frame(10), 40, 777);
        assert!(cache.get(&key("a", 0)).is_some());
        assert!(cache.get(&key("a", 0)).is_some());
        let c = cache.counters();
        assert_eq!((c.hits, c.misses, c.entries, c.bytes), (2, 1, 1, 40));
        assert_eq!(c.bytes_saved, 2 * 777, "each hit saves the recorded bytes");
    }

    #[test]
    fn evicts_least_recently_used_to_stay_bounded() {
        let cache = LruCache::new(100);
        cache.insert(key("a", 0), frame(1), 40, 0);
        cache.insert(key("b", 0), frame(1), 40, 0);
        assert!(cache.get(&key("a", 0)).is_some(), "touch a — b is now LRU");
        cache.insert(key("c", 0), frame(1), 40, 0);
        assert!(cache.get(&key("b", 0)).is_none(), "b evicted");
        assert!(cache.get(&key("a", 0)).is_some(), "a survived");
        assert!(cache.get(&key("c", 0)).is_some(), "c admitted");
        let c = cache.counters();
        assert_eq!(c.evictions, 1);
        assert!(c.bytes <= c.capacity_bytes);
    }

    #[test]
    fn oversized_entries_are_refused_and_reinsert_replaces() {
        let cache = LruCache::new(100);
        cache.insert(key("big", 0), frame(1), 101, 0);
        assert_eq!(cache.counters().entries, 0, "over-capacity entry refused");
        assert_eq!(cache.counters().refusals, 1, "refusal counted");
        cache.insert(key("a", 0), frame(1), 60, 0);
        cache.insert(key("a", 0), frame(1), 80, 0);
        let c = cache.counters();
        assert_eq!((c.entries, c.bytes), (1, 80), "replacement, not double count");
        assert_eq!(c.evictions, 0, "replacing a key never evicts others");
    }

    #[test]
    fn a_changed_file_stamp_is_a_different_key() {
        let cache = LruCache::new(1000);
        let p = PathBuf::from("x");
        let s1 = FileStamp { len: 10, mtime: (100, 0) };
        let s2 = FileStamp { len: 10, mtime: (200, 5) };
        cache.insert(CacheKey::File(p.clone(), s1), frame(1), 10, 0);
        assert!(cache.get(&CacheKey::File(p.clone(), s1)).is_some());
        assert!(
            cache.get(&CacheKey::File(p, s2)).is_none(),
            "an overwritten file (new mtime) must never hit the stale entry"
        );
    }

    #[test]
    fn invalidate_drops_all_keys_for_a_file() {
        let cache = LruCache::new(1000);
        cache.insert(CacheKey::File(PathBuf::from("x"), FileStamp::default()), frame(1), 10, 0);
        cache.insert(key("x", 0), frame(1), 10, 0);
        cache.insert(key("x", 8), frame(1), 10, 0);
        cache.insert(key("y", 0), frame(1), 10, 0);
        cache.invalidate_file(Path::new("x"));
        let c = cache.counters();
        assert_eq!((c.entries, c.bytes), (1, 10), "only y remains");
        assert_eq!(c.invalidations, 3, "all three x-derived entries counted");
        assert_eq!(c.evictions, 0, "invalidation is not eviction");
        assert!(cache.get(&key("y", 0)).is_some());
    }
}
