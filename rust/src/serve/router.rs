//! Typed route + query extraction for the `/v1` API.
//!
//! `Route::resolve` turns `(method, raw path)` into a typed route or an
//! `(status, message)` error; resource names are percent-decoded per
//! segment *after* splitting (so an encoded `/` cannot cross a
//! boundary) and validated against traversal. [`Query`] gives handlers
//! typed access to `?key=value` parameters with 400-grade errors.

use crate::data::Region;

use super::http::percent_decode;

/// Handler-level result: `Err((http_status, message))` renders as a
/// JSON error body.
pub type HttpResult<T> = std::result::Result<T, (u16, String)>;

/// The `/v1` route table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// `GET /v1/archives` — cursor-paginated listing of the root dir.
    ListArchives,
    /// `GET /v1/archives/{name}/info` — JSON byte breakdown.
    ArchiveInfo { name: String },
    /// `GET /v1/archives/{name}/extract?region=..&field=..` — raw f32s.
    ArchiveExtract { name: String },
    /// `GET /v1/streams/{name}/steps` — timeline listing.
    StreamSteps { name: String },
    /// `GET /v1/streams/{name}/extract?step=S&region=..` — raw f32s.
    StreamExtract { name: String },
    /// `POST /v1/compress?name=..&codec=..&bound=..` — small payloads.
    Compress,
    /// `GET /v1/stats` — request + cache counters.
    Stats,
    /// `GET /v1/metrics` — Prometheus text exposition (`?format=json`
    /// for the JSON rendering of the same snapshot).
    Metrics,
}

/// A stored-file name from the URL: decoded, non-empty, and unable to
/// escape the serving root.
pub fn validate_name(raw: &str) -> HttpResult<String> {
    let name = percent_decode(raw);
    if name.is_empty()
        || name.contains('/')
        || name.contains('\\')
        || name.contains("..")
        || name.starts_with('.')
    {
        return Err((400, format!("invalid resource name {name:?}")));
    }
    Ok(name)
}

impl Route {
    pub fn resolve(method: &str, path: &str) -> HttpResult<Route> {
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        let route = match segs.as_slice() {
            ["v1", "archives"] => Route::ListArchives,
            ["v1", "archives", name, "info"] => Route::ArchiveInfo { name: validate_name(name)? },
            ["v1", "archives", name, "extract"] => {
                Route::ArchiveExtract { name: validate_name(name)? }
            }
            ["v1", "streams", name, "steps"] => Route::StreamSteps { name: validate_name(name)? },
            ["v1", "streams", name, "extract"] => {
                Route::StreamExtract { name: validate_name(name)? }
            }
            ["v1", "compress"] => Route::Compress,
            ["v1", "stats"] => Route::Stats,
            ["v1", "metrics"] => Route::Metrics,
            _ => return Err((404, format!("no route for {path:?}"))),
        };
        let want = if matches!(route, Route::Compress) { "POST" } else { "GET" };
        if method != want {
            return Err((405, format!("{path} expects {want}, got {method}")));
        }
        Ok(route)
    }
}

/// Percent-decoded `?key=value` pairs with typed accessors.
#[derive(Debug, Default)]
pub struct Query {
    pairs: Vec<(String, String)>,
}

impl Query {
    pub fn parse(raw: &str) -> Query {
        let pairs = raw
            .split('&')
            .filter(|p| !p.is_empty())
            .map(|p| match p.split_once('=') {
                Some((k, v)) => (percent_decode(k), percent_decode(v)),
                None => (percent_decode(p), String::new()),
            })
            .collect();
        Query { pairs }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn req(&self, key: &str) -> HttpResult<&str> {
        self.get(key)
            .ok_or_else(|| (400, format!("missing query parameter {key:?}")))
    }

    pub fn usize_opt(&self, key: &str) -> HttpResult<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| (400, format!("{key} expects a non-negative integer, got {v:?}"))),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> HttpResult<usize> {
        Ok(self.usize_opt(key)?.unwrap_or(default))
    }

    /// The optional `region=i0:i1,j0:j1,...` parameter, 400 on a
    /// malformed spelling (same contract as the CLI's `--region`).
    pub fn region_opt(&self, key: &str) -> HttpResult<Option<Region>> {
        match self.get(key) {
            None => Ok(None),
            Some(spec) => Region::parse(spec)
                .map(Some)
                .map_err(|e| (400, format!("bad region {spec:?}: {e:#}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_every_route() {
        assert_eq!(Route::resolve("GET", "/v1/archives").unwrap(), Route::ListArchives);
        assert_eq!(
            Route::resolve("GET", "/v1/archives/a.ardc/info").unwrap(),
            Route::ArchiveInfo { name: "a.ardc".into() }
        );
        assert_eq!(
            Route::resolve("GET", "/v1/archives/a.ardc/extract").unwrap(),
            Route::ArchiveExtract { name: "a.ardc".into() }
        );
        assert_eq!(
            Route::resolve("GET", "/v1/streams/run.tstr/steps").unwrap(),
            Route::StreamSteps { name: "run.tstr".into() }
        );
        assert_eq!(
            Route::resolve("GET", "/v1/streams/run.tstr/extract").unwrap(),
            Route::StreamExtract { name: "run.tstr".into() }
        );
        assert_eq!(Route::resolve("POST", "/v1/compress").unwrap(), Route::Compress);
        assert_eq!(Route::resolve("GET", "/v1/stats").unwrap(), Route::Stats);
        assert_eq!(Route::resolve("GET", "/v1/metrics").unwrap(), Route::Metrics);
        // trailing slash tolerated (empty segments are dropped)
        assert_eq!(Route::resolve("GET", "/v1/archives/").unwrap(), Route::ListArchives);
    }

    #[test]
    fn unknown_paths_and_wrong_methods() {
        assert_eq!(Route::resolve("GET", "/").unwrap_err().0, 404);
        assert_eq!(Route::resolve("GET", "/v2/archives").unwrap_err().0, 404);
        assert_eq!(Route::resolve("GET", "/v1/archives/a/b/c").unwrap_err().0, 404);
        assert_eq!(Route::resolve("POST", "/v1/archives").unwrap_err().0, 405);
        assert_eq!(Route::resolve("GET", "/v1/compress").unwrap_err().0, 405);
        assert_eq!(Route::resolve("DELETE", "/v1/stats").unwrap_err().0, 405);
        assert_eq!(Route::resolve("POST", "/v1/metrics").unwrap_err().0, 405);
    }

    #[test]
    fn name_validation_blocks_traversal() {
        assert!(validate_name("run.tstr").is_ok());
        assert_eq!(validate_name("..").unwrap_err().0, 400);
        assert_eq!(validate_name(".hidden").unwrap_err().0, 400);
        assert_eq!(validate_name("a%2Fb").unwrap_err().0, 400, "encoded slash");
        assert_eq!(validate_name("a%5Cb").unwrap_err().0, 400, "encoded backslash");
        assert_eq!(validate_name("%2e%2e").unwrap_err().0, 400, "encoded dots");
        // resolve applies the same validation in place
        assert_eq!(
            Route::resolve("GET", "/v1/archives/%2e%2e/info").unwrap_err().0,
            400
        );
    }

    #[test]
    fn typed_query_extraction() {
        let q = Query::parse("step=3&region=0%3A4%2C0%3A8&limit=10&empty");
        assert_eq!(q.get("step"), Some("3"));
        assert_eq!(q.req("step").unwrap(), "3");
        assert_eq!(q.req("missing").unwrap_err().0, 400);
        assert_eq!(q.usize_or("limit", 5).unwrap(), 10);
        assert_eq!(q.usize_or("absent", 5).unwrap(), 5);
        assert_eq!(q.get("empty"), Some(""));
        let r = q.region_opt("region").unwrap().unwrap();
        assert_eq!(r.shape(), vec![4, 8]);
        assert!(q.region_opt("nope").unwrap().is_none());

        let bad = Query::parse("step=x&region=5:1");
        assert_eq!(bad.usize_opt("step").unwrap_err().0, 400);
        assert_eq!(bad.region_opt("region").unwrap_err().0, 400, "reversed range");
    }
}
