//! Minimal HTTP/1.1 request parsing and response writing over any
//! `Read + Write` transport (std `TcpStream` in production, in-memory
//! cursors in tests). In-tree by design: the serving layer follows the
//! repo's offline-build policy, so no hyper/axum — just the subset of
//! RFC 9112 the `/v1` routes need (request line, headers,
//! `Content-Length` bodies, `Expect: 100-continue`), with hard caps on
//! header and body sizes so an abusive peer cannot balloon memory.

use std::io::{Read, Write};

use crate::util::json::Value;
use crate::Result;
use anyhow::{bail, ensure};

/// Header block cap: a legitimate `/v1` request line + headers fits in
/// well under a page; anything larger is rejected before it allocates.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Body cap for `POST /v1/compress` — "small payloads" per the route
/// contract (a bench-scale field is a few MB; 64 MiB leaves headroom).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// One parsed request. `path` and `query` are kept *raw* (still
/// percent-encoded): the router decodes per path segment, so an encoded
/// `%2F` can never smuggle a separator past name validation.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Raw path component of the request target (before `?`).
    pub path: String,
    /// Raw query component (after `?`, possibly empty).
    pub query: String,
    /// Header names lowercased; values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// `path?query` for request logs.
    pub fn target(&self) -> String {
        if self.query.is_empty() {
            self.path.clone()
        } else {
            format!("{}?{}", self.path, self.query)
        }
    }
}

/// Decode `%XX` escapes (and `+` as space, form-style). Invalid escapes
/// pass through literally — names are validated downstream anyway.
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() => {
                let hex = &s[i + 1..i + 3];
                match u8::from_str_radix(hex, 16) {
                    Ok(v) => {
                        out.push(v);
                        i += 3;
                    }
                    Err(_) => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn find_blank_line(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read one request from `stream`. `buf` is the connection read buffer
/// (a pool thread's scratch — reused across requests, never shrunk).
/// Writes `100 Continue` when the client asked for it, so plain `curl`
/// POSTs with bodies over 1 KB don't stall.
pub fn read_request<S: Read + Write>(stream: &mut S, buf: &mut Vec<u8>) -> Result<Request> {
    buf.clear();
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_blank_line(buf) {
            break pos;
        }
        ensure!(buf.len() <= MAX_HEADER_BYTES, "request header exceeds {MAX_HEADER_BYTES} bytes");
        let n = stream.read(&mut chunk)?;
        ensure!(n > 0, "connection closed before end of header");
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..header_end])?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    ensure!(!method.is_empty() && !target.is_empty(), "malformed request line {request_line:?}");
    ensure!(
        version.starts_with("HTTP/1."),
        "unsupported protocol version {version:?}"
    );
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("malformed header line {line:?}"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let mut req = Request { method, path, query, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        bail!("transfer-encoding is not supported; send content-length");
    }
    let content_length: usize = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad content-length {v:?}"))?,
    };
    ensure!(
        content_length <= MAX_BODY_BYTES,
        "body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
    );
    if req
        .header("expect")
        .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
    {
        stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
        stream.flush()?;
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        ensure!(n > 0, "connection closed mid-body ({}/{} bytes)", body.len(), content_length);
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    req.body = body;
    Ok(req)
}

/// One response. Always `Connection: close` — one request per
/// connection keeps the dispatcher's batch model simple, and every
/// route's cost is dominated by decode work, not connection setup.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    /// Extra headers beyond content-type/length (e.g. `x-cache`).
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(v: &Value) -> Self {
        let mut body = v.to_string_pretty().into_bytes();
        body.push(b'\n');
        Response {
            status: 200,
            content_type: "application/json",
            headers: Vec::new(),
            body,
        }
    }

    /// Plain-text body (the Prometheus exposition on `/v1/metrics`).
    pub fn text(body: String) -> Self {
        Response {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    /// Raw little-endian payload bytes (f32 regions/frames).
    pub fn octets(body: Vec<u8>) -> Self {
        Response {
            status: 200,
            content_type: "application/octet-stream",
            headers: Vec::new(),
            body,
        }
    }

    pub fn error(status: u16, msg: &str) -> Self {
        let mut r = Response::json(&crate::util::json::obj(vec![(
            "error",
            crate::util::json::s(msg),
        )]));
        r.status = status;
        r
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Self {
        self.headers.push((name.to_string(), value.into()));
        self
    }

    pub fn status_text(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            422 => "Unprocessable Entity",
            500 => "Internal Server Error",
            501 => "Not Implemented",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: close\r\n",
            self.status,
            Self::status_text(self.status),
            self.content_type,
            self.body.len()
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Request> {
        let mut stream = Cursor::new(raw.to_vec());
        let mut buf = Vec::new();
        read_request(&mut stream, &mut buf)
    }

    #[test]
    fn parses_get_with_query() {
        let req = parse(
            b"GET /v1/streams/run.tstr/extract?step=3&region=0:4,0:8 HTTP/1.1\r\n\
              Host: localhost\r\nAccept: */*\r\n\r\n",
        )
        .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/streams/run.tstr/extract");
        assert_eq!(req.query, "step=3&region=0:4,0:8");
        assert_eq!(req.header("host"), Some("localhost"));
        assert_eq!(req.header("HOST"), Some("localhost"));
        assert!(req.body.is_empty());
        assert_eq!(req.target(), "/v1/streams/run.tstr/extract?step=3&region=0:4,0:8");
    }

    #[test]
    fn parses_post_body_with_length() {
        let req = parse(
            b"POST /v1/compress?name=a.ardc HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn rejects_oversized_header_and_bad_lines() {
        let mut big = b"GET /x HTTP/1.1\r\npad: ".to_vec();
        big.resize(big.len() + MAX_HEADER_BYTES + 1024, b'a');
        big.extend_from_slice(b"\r\n\r\n");
        assert!(parse(&big).is_err());
        assert!(parse(b"BROKEN\r\n\r\n").is_err(), "no target");
        assert!(parse(b"GET /x SPDY/9\r\n\r\n").is_err(), "bad version");
        assert!(parse(b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        assert!(
            parse(b"POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n").is_err(),
            "chunked unsupported"
        );
        assert!(
            parse(b"POST /x HTTP/1.1\r\ncontent-length: 99999999999999\r\n\r\n").is_err(),
            "body cap"
        );
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c"), "a b c");
        assert_eq!(percent_decode("0%3A4%2C0%3A8"), "0:4,0:8");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
        assert_eq!(percent_decode("%2"), "%2");
    }

    #[test]
    fn response_wire_format() {
        let r = Response::octets(vec![1, 2, 3]).with_header("x-cache", "hit");
        let mut out = Vec::new();
        r.write_to(&mut out).unwrap();
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 3\r\n"));
        assert!(text.contains("connection: close\r\n"));
        assert!(text.contains("x-cache: hit\r\n"));
        assert!(out.ends_with(&[1, 2, 3]));

        let e = Response::error(404, "no archive");
        let mut out = Vec::new();
        e.write_to(&mut out).unwrap();
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("\"error\": \"no archive\""));
    }
}
