//! The `serve` mode: a long-running HTTP server over a root directory
//! of archives and temporal streams.
//!
//! Concurrency model: an acceptor thread feeds connections into a
//! channel; a dispatcher drains them in batches and fans each batch out
//! onto the crate-wide [`Executor`] worker pool, so request handling
//! reuses the same threads and per-thread [`Scratch`] arenas as the
//! decode kernels it calls into (nested decode parallelism runs inline
//! on the pool, by the executor's design). Hot state is shared through
//! [`LruCache`]: open stream readers and parsed archives by path,
//! decoded keyframe regions by `(path, step, region class)` — a warm
//! `(step, region)` extract decodes only the residual chain, touching
//! zero keyframe payload bytes.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::codec::{
    archive_stats, AdaptiveCodec, Codec, CodecBuilder, ErrorBound, Sz3Codec, ZfpCodec,
};
use crate::compressor::format::STREAM_MAGIC;
use crate::compressor::Archive;
use crate::config::{self, DatasetKind, Scale};
use crate::data::Region;
use crate::engine::{Executor, Scratch};
use crate::obs::{self, expo, log};
use crate::stream::StreamReader;
use crate::tensor::Tensor;
use crate::util::json::{self, Value};
use crate::util::parallel;
use crate::Result;

use super::cache::{CacheCounters, CacheKey, CacheValue, FileStamp, LruCache};
use super::http::{self, Request, Response};
use super::info;
use super::router::{validate_name, HttpResult, Query, Route};

/// `cli serve` knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding the `.ardc` / `.tstr` files to serve.
    pub root: PathBuf,
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 picks a free port).
    pub addr: String,
    /// Max connections dispatched per executor batch (0 = thread count).
    pub batch: usize,
    /// LRU cache capacity in bytes.
    pub cache_bytes: usize,
    /// Overload backpressure: connections accepted while this many are
    /// already queued or in flight are shed immediately with a `503` +
    /// `Retry-After` instead of growing the queue without bound.
    pub max_pending: usize,
}

impl ServeConfig {
    pub fn new(root: impl Into<PathBuf>, addr: impl Into<String>) -> Self {
        Self {
            root: root.into(),
            addr: addr.into(),
            batch: 0,
            cache_bytes: 256 * 1024 * 1024,
            max_pending: 128,
        }
    }
}

const REQUESTS_HELP: &str = "HTTP requests handled, by status class";
const REQ_DUR_HELP: &str = "End-to-end request wall time by route";
const KF_BYTES_HELP: &str = "Compressed keyframe payload bytes decoded (cache misses only)";

/// Stable `route` label values for `attn_request_duration_seconds`,
/// preregistered at bind so scrapers see the full catalog immediately.
const ROUTE_LABELS: [&str; 10] = [
    "archives_list",
    "archive_info",
    "archive_extract",
    "stream_steps",
    "stream_extract",
    "compress",
    "stats",
    "metrics",
    "unroutable",
    "bad_request",
];

/// Per-server request counters, registered in the server's own
/// [`obs::Registry`] so concurrent servers in one process (tests) don't
/// see each other's traffic. Pipeline stage histograms stay global.
struct Metrics {
    status_2xx: &'static obs::Counter,
    status_4xx: &'static obs::Counter,
    status_5xx: &'static obs::Counter,
    /// Compressed keyframe payload bytes actually decoded (cache misses
    /// pay `region_cost.bytes_touched`; hits pay zero).
    kf_payload_bytes: &'static obs::Counter,
    /// Connections shed by overload backpressure (503 before routing).
    shed: &'static obs::Counter,
}

struct Shared {
    root: PathBuf,
    cache: LruCache,
    /// This server's registry: request counters and per-route latency
    /// histograms. `/v1/metrics` composes it with the cache snapshot
    /// and the process-global registry.
    registry: obs::Registry,
    metrics: Metrics,
    /// Connections accepted but not yet finished handling — the
    /// backpressure gauge the acceptor sheds against.
    pending: AtomicUsize,
}

/// A bound-but-not-yet-running server; [`Server::run`] blocks until
/// [`StopHandle::stop`] is called.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    batch: usize,
    max_pending: usize,
}

/// Cloneable handle that wakes the accept loop and shuts the server
/// down.
#[derive(Clone)]
pub struct StopHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl StopHandle {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() with a throwaway connection
        let _ = TcpStream::connect(self.addr);
    }
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> Result<Self> {
        anyhow::ensure!(
            cfg.root.is_dir(),
            "serve root {} is not a directory",
            cfg.root.display()
        );
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let batch = if cfg.batch == 0 { parallel::num_threads() } else { cfg.batch };
        if std::env::var_os("ATTN_REDUCE_QUIET").is_some() {
            log::set_level(log::Level::Error);
        }
        // materialize the full metric catalog before any traffic so the
        // first scrape already carries every family at zero
        obs::preregister();
        let registry = obs::Registry::new();
        let status = |class: &str| {
            registry.counter("attn_requests_total", REQUESTS_HELP, &[("status", class)])
        };
        let metrics = Metrics {
            status_2xx: status("2xx"),
            status_4xx: status("4xx"),
            status_5xx: status("5xx"),
            kf_payload_bytes: registry
                .counter("attn_keyframe_payload_bytes_total", KF_BYTES_HELP, &[]),
            shed: registry.counter("attn_requests_shed_total", obs::REQUESTS_SHED_HELP, &[]),
        };
        for label in ROUTE_LABELS {
            registry.histogram(
                "attn_request_duration_seconds",
                REQ_DUR_HELP,
                &[("route", label)],
                obs::DURATION_BOUNDS_NS,
                obs::SCALE_NS_TO_SECONDS,
            );
        }
        Ok(Server {
            listener,
            addr,
            shared: Arc::new(Shared {
                root: cfg.root,
                cache: LruCache::new(cfg.cache_bytes),
                registry,
                metrics,
                pending: AtomicUsize::new(0),
            }),
            stop: Arc::new(AtomicBool::new(false)),
            batch: batch.max(1),
            max_pending: cfg.max_pending.max(1),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop_handle(&self) -> StopHandle {
        StopHandle { stop: self.stop.clone(), addr: self.addr }
    }

    /// Accept until stopped, shedding load once the pending-connection
    /// queue saturates. Shutdown is a graceful drain: the accept loop
    /// stops taking new connections, the channel closes, and the
    /// dispatcher finishes every connection already accepted (queued or
    /// in flight) before [`Server::run`] returns — a stopped server
    /// never drops a request it said yes to.
    pub fn run(self) -> Result<()> {
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let shared = self.shared.clone();
        let batch = self.batch;
        let dispatcher = std::thread::Builder::new()
            .name("serve-dispatch".to_string())
            .spawn(move || dispatch_loop(rx, shared, batch))?;
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(mut stream) = conn else { continue };
            // backpressure: answer over-capacity connections straight
            // from the acceptor thread (tiny fixed response, short
            // write timeout) rather than queueing without bound
            if self.shared.pending.load(Ordering::Acquire) >= self.max_pending {
                shed(&self.shared, &mut stream);
                continue;
            }
            self.shared.pending.fetch_add(1, Ordering::AcqRel);
            let _ = tx.send(stream);
        }
        drop(tx); // dispatcher drains the queue, then exits
        dispatcher
            .join()
            .map_err(|_| anyhow::anyhow!("serve dispatcher panicked"))?;
        Ok(())
    }
}

/// Overload response (`503` + `Retry-After`), written on the acceptor
/// thread so a saturated worker pool cannot delay it.
fn shed(shared: &Shared, stream: &mut TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let resp = Response::error(503, "server overloaded; retry shortly")
        .with_header("retry-after", "1");
    let _ = resp.write_to(stream);
    shared.metrics.shed.inc();
    shared.metrics.status_5xx.inc();
    obs::request_shed();
    crate::log_at!(log::Level::Warn, "serve", "event=request_shed status=503");
}

/// Decrements the pending-connection gauge when handling ends, however
/// it ends (normal return or handler panic — the unwind runs Drop).
struct PendingGuard<'a>(&'a AtomicUsize);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

fn dispatch_loop(rx: mpsc::Receiver<TcpStream>, shared: Arc<Shared>, batch_cap: usize) {
    loop {
        let Ok(first) = rx.recv() else {
            return; // acceptor gone
        };
        // opportunistically batch whatever else is already queued
        let mut batch = vec![std::sync::Mutex::new(Some(first))];
        while batch.len() < batch_cap {
            match rx.try_recv() {
                Ok(s) => batch.push(std::sync::Mutex::new(Some(s))),
                Err(_) => break,
            }
        }
        let shared_ref = &shared;
        let batch_ref = &batch;
        let outcomes = Executor::global().par_map_isolated(batch.len(), move |i, scratch| {
            if let Some(mut stream) = batch_ref[i].lock().unwrap().take() {
                let _pending = PendingGuard(&shared_ref.pending);
                handle_connection(shared_ref, &mut stream, scratch);
            }
        });
        for outcome in outcomes {
            if let Err(panic_msg) = outcome {
                // the connection died without a response; the server
                // itself must keep going
                crate::log_at!(log::Level::Warn, "serve", "event=handler_panic msg={panic_msg:?}");
            }
        }
    }
}

fn handle_connection(shared: &Shared, stream: &mut TcpStream, scratch: &mut Scratch) {
    let _span = crate::obs::stages::SERVE_REQUEST.span();
    let rid = log::next_request_id();
    let t0 = Instant::now();
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let (target, method, response, note, route_label) =
        match http::read_request(stream, &mut scratch.bytes) {
            Ok(req) => {
                let (resp, note, label) = respond(shared, &req);
                (req.target(), req.method.clone(), resp, note, label)
            }
            Err(e) => (
                "-".to_string(),
                "?".to_string(),
                Response::error(400, &format!("{e:#}")),
                "-",
                "bad_request",
            ),
        };
    let _ = response.write_to(stream);
    let m = &shared.metrics;
    match response.status {
        200..=299 => m.status_2xx.inc(),
        400..=499 => m.status_4xx.inc(),
        _ => m.status_5xx.inc(),
    };
    let elapsed = t0.elapsed();
    shared
        .registry
        .histogram(
            "attn_request_duration_seconds",
            REQ_DUR_HELP,
            &[("route", route_label)],
            obs::DURATION_BOUNDS_NS,
            obs::SCALE_NS_TO_SECONDS,
        )
        .observe(elapsed.as_nanos() as u64);
    crate::log_at!(
        log::Level::Info,
        "serve",
        "req={rid} method={method} target={target} status={} bytes={} dur_us={} cache={note}",
        response.status,
        response.body.len(),
        elapsed.as_micros()
    );
}

/// Stable metric label for a resolved route (`ROUTE_LABELS` lists the
/// full value set).
fn route_label(route: &Route) -> &'static str {
    match route {
        Route::ListArchives => "archives_list",
        Route::ArchiveInfo { .. } => "archive_info",
        Route::ArchiveExtract { .. } => "archive_extract",
        Route::StreamSteps { .. } => "stream_steps",
        Route::StreamExtract { .. } => "stream_extract",
        Route::Compress => "compress",
        Route::Stats => "stats",
        Route::Metrics => "metrics",
    }
}

/// Route + dispatch. The second element is the request log's cache
/// column (`hit` / `miss` for cacheable routes, `-` otherwise); the
/// third is the route's metric label.
fn respond(shared: &Shared, req: &Request) -> (Response, &'static str, &'static str) {
    let route = match Route::resolve(&req.method, &req.path) {
        Ok(r) => r,
        Err((status, msg)) => return (Response::error(status, &msg), "-", "unroutable"),
    };
    let label = route_label(&route);
    let query = Query::parse(&req.query);
    let out = match route {
        Route::ListArchives => list_archives(shared, &query).map(|r| (r, "-")),
        Route::ArchiveInfo { name } => archive_info(shared, &name).map(|r| (r, "-")),
        Route::ArchiveExtract { name } => archive_extract(shared, &name, &query),
        Route::StreamSteps { name } => stream_steps(shared, &name, &query),
        Route::StreamExtract { name } => stream_extract(shared, &name, &query),
        Route::Compress => compress(shared, &query, &req.body).map(|r| (r, "-")),
        Route::Stats => stats(shared).map(|r| (r, "-")),
        Route::Metrics => metrics(shared, &query).map(|r| (r, "-")),
    };
    match out {
        Ok((resp, note)) => (resp, note, label),
        Err((status, msg)) => (Response::error(status, &msg), "-", label),
    }
}

/// Map a library error onto an HTTP status (handlers pre-classify 4xx
/// cases): detected data corruption — a typed
/// [`crate::compressor::format::Corruption`] anywhere in the chain — is
/// the *file's* fault, not the server's, and surfaces as `422` so
/// operators can tell "run `cli verify`" apart from real 500s.
fn internal<T>(r: Result<T>) -> HttpResult<T> {
    r.map_err(|e| {
        if crate::compressor::format::is_corruption(&e) {
            (422, format!("{e:#}"))
        } else {
            (500, format!("{e:#}"))
        }
    })
}

fn read_file(shared: &Shared, name: &str) -> HttpResult<(PathBuf, Vec<u8>)> {
    let path = shared.root.join(name);
    match std::fs::read(&path) {
        Ok(bytes) => Ok((path, bytes)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Err((404, format!("no file {name:?} under the serve root")))
        }
        Err(e) => Err((500, format!("reading {name:?}: {e}"))),
    }
}

/// The file's current content stamp — every cache key embeds it, so an
/// overwritten file (new len/mtime) can never hit a stale entry.
fn file_stamp(path: &Path, name: &str) -> HttpResult<FileStamp> {
    match FileStamp::of(path) {
        Ok(s) => Ok(s),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Err((404, format!("no file {name:?} under the serve root")))
        }
        Err(e) => Err((500, format!("stat {name:?}: {e}"))),
    }
}

/// Parse failures split by kind: checksum/framing damage is `422`
/// (verifiable corruption), anything else a plain `400`.
fn parse_status(e: &anyhow::Error) -> u16 {
    if crate::compressor::format::is_corruption(e) {
        422
    } else {
        400
    }
}

fn is_stream_bytes(bytes: &[u8]) -> bool {
    bytes.len() >= 4 && &bytes[0..4] == STREAM_MAGIC
}

/// Canonical `lo:hi,...` spelling — the cache's region class (an
/// explicit full region and a defaulted one share an entry).
fn region_class(region: &Region) -> String {
    region
        .lo
        .iter()
        .zip(&region.hi)
        .map(|(l, h)| format!("{l}:{h}"))
        .collect::<Vec<_>>()
        .join(",")
}

fn tensor_bytes(t: &Tensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(t.len() * 4);
    for v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

// -- GET /v1/archives -------------------------------------------------------

fn list_archives(shared: &Shared, query: &Query) -> HttpResult<Response> {
    let limit = query.usize_or("limit", 100)?.clamp(1, 1000);
    let cursor = query.get("cursor").map(http::percent_decode);
    let dir = std::fs::read_dir(&shared.root)
        .map_err(|e| (500, format!("reading serve root: {e}")))?;
    let mut files: Vec<(String, u64)> = Vec::new();
    for entry in dir.flatten() {
        let Ok(name) = entry.file_name().into_string() else {
            continue;
        };
        if !(name.ends_with(".ardc") || name.ends_with(".tstr")) {
            continue;
        }
        let size = entry.metadata().map(|m| m.len()).unwrap_or(0);
        files.push((name, size));
    }
    files.sort();
    let start = match &cursor {
        Some(c) => files.partition_point(|(n, _)| n.as_str() <= c.as_str()),
        None => 0,
    };
    let page = &files[start..(start + limit).min(files.len())];
    let mut items = Vec::new();
    for (name, size) in page {
        // classify by magic, not extension: a `.ardc`-named stream (the
        // golden corpus has one) must route to /v1/streams
        let mut magic = [0u8; 4];
        let kind = match std::fs::File::open(shared.root.join(name))
            .and_then(|mut f| f.read_exact(&mut magic))
        {
            Ok(()) if &magic == STREAM_MAGIC => "stream",
            Ok(()) => "archive",
            Err(_) => "unknown",
        };
        items.push(json::obj(vec![
            ("name", json::s(name.clone())),
            ("bytes", json::num(*size as f64)),
            ("kind", json::s(kind)),
        ]));
    }
    let next_cursor = if start + page.len() < files.len() {
        page.last()
            .map(|(n, _)| json::s(n.clone()))
            .unwrap_or(Value::Null)
    } else {
        Value::Null
    };
    Ok(Response::json(&json::obj(vec![
        ("archives", Value::Arr(items)),
        ("total", json::num(files.len() as f64)),
        ("next_cursor", next_cursor),
    ])))
}

// -- GET /v1/archives/{name}/info -------------------------------------------

fn archive_info(shared: &Shared, name: &str) -> HttpResult<Response> {
    let (_, bytes) = read_file(shared, name)?;
    let doc = internal(info::info_json(&bytes))?;
    Ok(Response::json(&doc))
}

// -- shared loaders ---------------------------------------------------------

/// The parsed archive for `name`, through the cache. Second element:
/// was it a cache hit?
fn load_archive(shared: &Shared, name: &str) -> HttpResult<(PathBuf, Arc<Archive>, bool)> {
    let path = shared.root.join(name);
    let stamp = file_stamp(&path, name)?;
    let key = CacheKey::File(path.clone(), stamp);
    if let Some(CacheValue::Archive(a)) = shared.cache.get(&key) {
        return Ok((path, a, true));
    }
    let (path, bytes) = read_file(shared, name)?;
    if is_stream_bytes(&bytes) {
        return Err((400, format!("{name:?} is a temporal stream; use /v1/streams/{name}/...")));
    }
    let archive = Arc::new(
        Archive::from_bytes(&bytes)
            .map_err(|e| (parse_status(&e), format!("bad archive {name:?}: {e:#}")))?,
    );
    let cost = bytes.len();
    shared.cache.insert(key, CacheValue::Archive(archive.clone()), cost, cost);
    Ok((path, archive, false))
}

/// The open stream reader for `name`, through the cache.
fn load_reader(
    shared: &Shared,
    name: &str,
) -> HttpResult<(PathBuf, FileStamp, Arc<StreamReader>, bool)> {
    let path = shared.root.join(name);
    let stamp = file_stamp(&path, name)?;
    let key = CacheKey::File(path.clone(), stamp);
    if let Some(CacheValue::Reader(r)) = shared.cache.get(&key) {
        return Ok((path, stamp, r, true));
    }
    let (path, bytes) = read_file(shared, name)?;
    if !is_stream_bytes(&bytes) {
        let msg = format!("{name:?} is not a temporal stream; use /v1/archives/{name}/...");
        return Err((400, msg));
    }
    let cost = bytes.len();
    let reader = Arc::new(
        StreamReader::from_bytes(bytes)
            .map_err(|e| (parse_status(&e), format!("bad stream {name:?}: {e:#}")))?,
    );
    shared.cache.insert(key, CacheValue::Reader(reader.clone()), cost, cost);
    Ok((path, stamp, reader, false))
}

fn require_served_codec(codec_id: &str) -> HttpResult<()> {
    if codec_id == "sz3" || codec_id == "zfp" || codec_id == "adaptive" {
        Ok(())
    } else {
        Err((
            501,
            format!(
                "serving decodes the pure-rust codecs (sz3|zfp|adaptive); {codec_id:?} \
                 archives need checkpoints and go through the CLI"
            ),
        ))
    }
}

// -- GET /v1/archives/{name}/extract ----------------------------------------

fn archive_extract(
    shared: &Shared,
    name: &str,
    query: &Query,
) -> HttpResult<(Response, &'static str)> {
    let (_, archive, hit) = load_archive(shared, name)?;
    let codec_id = archive
        .header
        .get("codec")
        .and_then(|v| v.as_str())
        .unwrap_or("?")
        .to_string();
    require_served_codec(&codec_id)?;
    let dsv = archive.header.req("dataset").map_err(|e| (400, format!("{e:#}")))?;
    let dataset = internal(config::DatasetConfig::from_json(dsv))?;
    let region = match query.region_opt("region")? {
        Some(r) => {
            r.validate_in(&dataset.dims).map_err(|e| (400, format!("{e:#}")))?;
            r
        }
        None => Region::full(&dataset.dims),
    };
    let mut b = CodecBuilder::new();
    let codec = internal(b.for_archive(&archive))?;
    let tensor = if archive.is_multi_field() {
        let names = internal(archive.field_names())?;
        let field = query.req("field").map_err(|_| {
            (400, format!("multi-field archive: field=NAME required (have: {names:?})"))
        })?;
        // resolve by name first, then as a numeric index (mirrors the
        // CLI's --field); an out-of-range index is a client error and
        // names the field count so callers can correct it
        let i = match names.iter().position(|n| n == field) {
            Some(i) => i,
            None => match field.parse::<usize>() {
                Ok(ix) if ix < names.len() => ix,
                Ok(ix) => {
                    let n = names.len();
                    let msg =
                        format!("field index {ix} out of range: archive has {n} fields (0..{n})");
                    return Err((400, msg));
                }
                Err(_) => return Err((404, format!("no field {field:?} (have: {names:?})"))),
            },
        };
        let sub = internal(archive.field_archive(i))?;
        internal(codec.decompress_region(&sub, &region))?
    } else {
        if query.get("field").is_some() {
            return Err((400, "field= only applies to multi-field (v2) archives".to_string()));
        }
        internal(codec.decompress_region(&archive, &region))?
    };
    let resp = Response::octets(tensor_bytes(&tensor))
        .with_header("x-cache", if hit { "hit" } else { "miss" })
        .with_header("x-points", tensor.len().to_string());
    Ok((resp, if hit { "hit" } else { "miss" }))
}

// -- GET /v1/streams/{name}/steps -------------------------------------------

fn stream_steps(
    shared: &Shared,
    name: &str,
    query: &Query,
) -> HttpResult<(Response, &'static str)> {
    let (_, _, reader, hit) = load_reader(shared, name)?;
    let n = reader.n_steps();
    let cursor = query.usize_or("cursor", 0)?.min(n);
    let limit = query.usize_or("limit", 256)?.clamp(1, 4096);
    let end = (cursor + limit).min(n);
    let steps: Vec<Value> = reader.timeline().entries[cursor..end]
        .iter()
        .enumerate()
        .map(|(i, e)| {
            json::obj(vec![
                ("step", json::num((cursor + i) as f64)),
                ("keyframe", Value::Bool(e.keyframe)),
                ("bytes", json::num(e.len as f64)),
            ])
        })
        .collect();
    let next_cursor = if end < n { json::num(end as f64) } else { Value::Null };
    let doc = json::obj(vec![
        ("name", json::s(name)),
        ("codec", json::s(reader.codec_id())),
        ("bound", json::s(reader.bound().to_string())),
        ("dims", json::arr_usize(&reader.dataset().dims)),
        ("n_steps", json::num(n as f64)),
        ("keyint", json::num(reader.keyframe_interval() as f64)),
        ("finished", Value::Bool(reader.is_finished())),
        ("steps", Value::Arr(steps)),
        ("next_cursor", next_cursor),
    ]);
    Ok((Response::json(&doc), if hit { "hit" } else { "miss" }))
}

// -- GET /v1/streams/{name}/extract -----------------------------------------

fn stream_extract(
    shared: &Shared,
    name: &str,
    query: &Query,
) -> HttpResult<(Response, &'static str)> {
    let (path, stamp, reader, _) = load_reader(shared, name)?;
    require_served_codec(reader.codec_id())?;
    let step = query
        .req("step")?
        .parse::<usize>()
        .map_err(|_| (400, "step expects a non-negative integer".to_string()))?;
    if step >= reader.n_steps() {
        let msg = format!("step {step} out of range ({} steps in stream)", reader.n_steps());
        return Err((400, msg));
    }
    let region = match query.region_opt("region")? {
        Some(r) => {
            r.validate_in(&reader.dataset().dims).map_err(|e| (400, format!("{e:#}")))?;
            r
        }
        None => Region::full(&reader.dataset().dims),
    };
    let mut b = CodecBuilder::new();
    let codec = internal(reader.build_codec(&mut b))?;
    let kstep = internal(reader.keyframe_step(step))?;

    // the keyframe is the reusable prefix of every chain that starts at
    // it: cache the decoded region once, then warm requests pay only
    // the residual steps
    let key = CacheKey::Keyframe(path, stamp, kstep, region_class(&region));
    let (base, hit, kf_bytes) = match shared.cache.get(&key) {
        Some(CacheValue::Frame(f)) => (f, true, 0usize),
        _ => {
            let cost = internal(reader.region_cost(kstep, &region))?;
            let frame = Arc::new(internal(reader.extract(&*codec, kstep, &region))?);
            shared.cache.insert(
                key,
                CacheValue::Frame(frame.clone()),
                frame.len() * 4,
                cost.bytes_touched,
            );
            (frame, false, cost.bytes_touched)
        }
    };
    shared.metrics.kf_payload_bytes.add(kf_bytes as u64);
    let tensor = if step == kstep {
        (*base).clone()
    } else {
        internal(reader.extract_from(&*codec, &base, kstep, step, &region))?
    };
    let resp = Response::octets(tensor_bytes(&tensor))
        .with_header("x-cache", if hit { "hit" } else { "miss" })
        .with_header("x-keyframe-payload-bytes", kf_bytes.to_string())
        .with_header("x-chain-steps", (step - kstep + 1).to_string())
        .with_header("x-points", tensor.len().to_string());
    Ok((resp, if hit { "hit" } else { "miss" }))
}

// -- POST /v1/compress ------------------------------------------------------

fn compress(shared: &Shared, query: &Query, body: &[u8]) -> HttpResult<Response> {
    let name = validate_name(query.req("name")?)?;
    let codec_id = query.get("codec").unwrap_or("sz3").to_string();
    require_served_codec(&codec_id)?;
    let kind = DatasetKind::parse(query.get("dataset").unwrap_or("s3d"))
        .map_err(|e| (400, format!("{e:#}")))?;
    let scale = Scale::parse(query.get("scale").unwrap_or("bench"))
        .map_err(|e| (400, format!("{e:#}")))?;
    let bound = ErrorBound::parse(query.get("bound").unwrap_or("nrmse:1e-3"))
        .map_err(|e| (400, format!("{e:#}")))?;
    let cfg = config::dataset_preset(kind, scale);
    let expect = cfg.total_points() * 4;
    if body.len() != expect {
        return Err((
            400,
            format!(
                "body holds {} bytes; dataset {}/{:?} expects {expect} (dims {:?} as raw \
                 little-endian f32)",
                body.len(),
                kind.name(),
                scale,
                cfg.dims
            ),
        ));
    }
    let data: Vec<f32> = body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let field = Tensor::new(cfg.dims.clone(), data);
    let archive = internal(match codec_id.as_str() {
        "sz3" => Sz3Codec::new(cfg.clone()).compress(&field, &bound),
        "adaptive" => AdaptiveCodec::new(cfg.clone()).compress(&field, &bound),
        _ => ZfpCodec::new(cfg.clone()).compress(&field, &bound),
    })?;
    let path = shared.root.join(&name);
    // `save` is atomic (temp + fsync + rename): a failure here leaves
    // the previous file — and thus every stamped cache entry — intact,
    // never a half-written archive under the final name
    internal(archive.save(&path))?;
    // drop entries for the overwritten content eagerly; the stamp baked
    // into each key already guarantees they could never be served
    shared.cache.invalidate_file(&path);
    let stats = internal(archive_stats(&archive))?;
    Ok(Response::json(&json::obj(vec![
        ("name", json::s(name)),
        ("codec", json::s(codec_id)),
        ("bound", json::s(bound.to_string())),
        ("bytes", json::num(stats.archive_bytes as f64)),
        ("cr", json::num(stats.cr)),
        ("cr_total", json::num(stats.cr_total)),
    ])))
}

// -- GET /v1/stats ----------------------------------------------------------

fn stats(shared: &Shared) -> HttpResult<Response> {
    let m = &shared.metrics;
    let (n2, n4, n5) = (m.status_2xx.get(), m.status_4xx.get(), m.status_5xx.get());
    let c = shared.cache.counters();
    let lookups = c.hits + c.misses;
    let hit_rate = if lookups == 0 { 0.0 } else { c.hits as f64 / lookups as f64 };
    Ok(Response::json(&json::obj(vec![
        ("requests", json::num((n2 + n4 + n5) as f64)),
        (
            "responses",
            json::obj(vec![
                ("ok_2xx", json::num(n2 as f64)),
                ("client_4xx", json::num(n4 as f64)),
                ("server_5xx", json::num(n5 as f64)),
            ]),
        ),
        (
            "cache",
            json::obj(vec![
                ("entries", json::num(c.entries as f64)),
                ("bytes", json::num(c.bytes as f64)),
                ("capacity_bytes", json::num(c.capacity_bytes as f64)),
                ("hits", json::num(c.hits as f64)),
                ("misses", json::num(c.misses as f64)),
                ("hit_rate", json::num(hit_rate)),
                ("evictions", json::num(c.evictions as f64)),
                ("refusals", json::num(c.refusals as f64)),
                ("invalidations", json::num(c.invalidations as f64)),
                ("bytes_saved", json::num(c.bytes_saved as f64)),
            ]),
        ),
        ("keyframe_payload_bytes_decoded", json::num(m.kf_payload_bytes.get() as f64)),
    ])))
}

// -- GET /v1/metrics --------------------------------------------------------

/// The LRU cache's counter snapshot as hand-built metric families (the
/// cache's `Mutex`'d counters stay the single source of truth; they are
/// re-rendered on every scrape rather than double-counted).
fn cache_families(c: &CacheCounters) -> Vec<obs::FamilySnapshot> {
    vec![
        expo::counter_family("attn_cache_hits_total", "Cache lookups that hit", c.hits),
        expo::counter_family("attn_cache_misses_total", "Cache lookups that missed", c.misses),
        expo::counter_family(
            "attn_cache_evictions_total",
            "Entries evicted to admit new ones",
            c.evictions,
        ),
        expo::counter_family("attn_cache_insertions_total", "Entries admitted", c.insertions),
        expo::counter_family(
            "attn_cache_refusals_total",
            "Inserts refused because one entry exceeded the capacity",
            c.refusals,
        ),
        expo::counter_family(
            "attn_cache_invalidations_total",
            "Entries dropped by file-overwrite invalidation",
            c.invalidations,
        ),
        expo::counter_family(
            "attn_cache_bytes_saved_total",
            "Compressed payload bytes hits avoided decoding",
            c.bytes_saved,
        ),
        expo::gauge_family("attn_cache_entries", "Resident cache entries", c.entries as f64),
        expo::gauge_family("attn_cache_resident_bytes", "Resident cache bytes", c.bytes as f64),
        expo::gauge_family(
            "attn_cache_capacity_bytes",
            "Configured cache capacity",
            c.capacity_bytes as f64,
        ),
    ]
}

/// Prometheus text exposition (`?format=json` for the JSON mirror):
/// this server's request metrics + the cache snapshot + the
/// process-global pipeline registry, one sorted document.
fn metrics(shared: &Shared, query: &Query) -> HttpResult<Response> {
    let mut fams = shared.registry.snapshot();
    fams.extend(cache_families(&shared.cache.counters()));
    fams.extend(obs::Registry::global().snapshot());
    match query.get("format") {
        None => Ok(Response::text(expo::render_text(&fams))),
        Some("json") => Ok(Response::json(&expo::render_json(&fams))),
        Some(other) => Err((400, format!("unknown format {other:?} (expected json)"))),
    }
}
