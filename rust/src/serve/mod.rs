//! HTTP serving layer: `compress` / `extract` / `info` as a
//! long-running service (`cli serve`).
//!
//! The read-mostly access pattern the paper's `(step, region)` random
//! access targets — scientists repeatedly pulling bounded-error regions
//! out of large compressed stores — only pays off when open readers and
//! decoded keyframes are reused across requests. This module provides
//! that reuse: a dependency-free HTTP/1.1 server (std `TcpListener`
//! plus in-tree parsing, per the offline-build policy) whose request
//! handling fans out onto the crate's [`Executor`] worker pool and
//! whose hot state lives in a byte-bounded LRU cache.
//!
//! Layout:
//!
//! * [`http`] — request parsing / response writing over `Read + Write`
//! * [`router`] — typed `/v1` route + query extraction
//! * [`cache`] — bounded LRU over readers, archives, decoded keyframes
//! * [`info`] — byte-breakdown summaries shared with `cli info`
//! * [`server`] — accept loop, executor dispatch, route handlers
//!
//! [`Executor`]: crate::engine::Executor

pub mod cache;
pub mod http;
pub mod info;
pub mod router;
pub mod server;

pub use cache::{CacheCounters, CacheKey, CacheValue, LruCache};
pub use server::{ServeConfig, Server, StopHandle};
