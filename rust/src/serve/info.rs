//! Shared byte-breakdown summaries behind `cli info --in` and the
//! `GET /v1/archives/{name}/info` route.
//!
//! The CLI's pinned text output and the route's JSON body are two
//! renderings of the same structs ([`EntropySummary`],
//! [`StreamByteSummary`]) computed here, so the numbers can never
//! drift between the two surfaces. [`info_json`] is the machine form:
//! `cli info --json --in F` prints it and the route returns it
//! verbatim.

use crate::baselines::{Sz3Like, ZfpLike};
use crate::codec::TileCodec;
use crate::compressor::format::{
    parse_stream_header, parse_stream_record, parse_stream_record_checked, BLOCK_INDEX_TAG,
    CR_SECTIONS, STREAM_KEY_TAG, STREAM_MAGIC, STREAM_RES_TAG, STREAM_TIDX_TAG,
    XSUM_HEADER_KEY,
};
use crate::compressor::Archive;
use crate::config::DatasetConfig;
use crate::util::json::{self, Value};
use crate::Result;

/// payload / index / other, from a section tag (v2 nested tags like
/// `F000/SZ3B` classify by their base name).
pub fn section_class(tag: &str) -> &'static str {
    let base = tag.rsplit('/').next().unwrap_or(tag);
    if base == BLOCK_INDEX_TAG {
        "index"
    } else if CR_SECTIONS.contains(&base) {
        "payload"
    } else {
        "other"
    }
}

/// The per-tile entropy split of a single-field sz3/zfp archive:
/// container modes and where the compressed bytes actually sit
/// (Huffman tables vs symbol stream vs raw/exponent planes vs tile
/// framing). `None` when the archive has no measurable entropy stream
/// (v2 container, learned codec, or no dataset header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EntropySummary {
    pub tiles: usize,
    pub plain: usize,
    pub zero_run: usize,
    pub constant: usize,
    /// Tiles riding the interleaved rANS container (magic 0xB7).
    pub rans: usize,
    /// Interleaved rANS lane count (fixed per build; 0 when no tile
    /// uses the rANS mode).
    pub rans_lanes: usize,
    pub table_bytes: usize,
    pub symbol_bytes: usize,
    pub aux_bytes: usize,
    pub framing_bytes: usize,
}

pub fn entropy_summary(archive: &Archive, codec: &str) -> Result<Option<EntropySummary>> {
    if archive.version() == 2 || (codec != "sz3" && codec != "zfp" && codec != "adaptive") {
        return Ok(None);
    }
    let Some(dsv) = archive.header.get("dataset") else {
        return Ok(None);
    };
    let Ok(ds) = DatasetConfig::from_json(dsv) else {
        return Ok(None);
    };
    let tag = match codec {
        "sz3" => "SZ3B",
        "zfp" => "ZFPB",
        _ => "ADPB",
    };
    let payload = archive.section(tag)?;
    let index = archive.block_index()?;
    let (spans, cap): (Vec<(usize, usize)>, usize) = match &index {
        Some(ix) => {
            // untrusted index: bound tile dims and byte spans against
            // the header geometry before slicing the payload
            ix.validate(&ds.dims, payload.len())?;
            (
                (0..ix.entries.len())
                    .map(|i| ix.entry(i))
                    .collect::<Result<_>>()?,
                ix.tile.iter().product(),
            )
        }
        None => (vec![(0, payload.len())], ds.total_points()),
    };
    // per-tile codec ids: an adaptive payload mixes sz3 and zfp streams,
    // so each span's breakdown must parse under the codec that wrote it
    let codec_ids = index.as_ref().and_then(|ix| ix.codecs.clone());
    if codec == "adaptive" && codec_ids.is_none() {
        return Ok(None);
    }
    let mut out = EntropySummary {
        tiles: spans.len(),
        plain: 0,
        zero_run: 0,
        constant: 0,
        rans: 0,
        rans_lanes: 0,
        table_bytes: 0,
        symbol_bytes: 0,
        aux_bytes: 0,
        framing_bytes: 0,
    };
    for (i, &(off, len)) in spans.iter().enumerate() {
        let use_sz3 = match (codec, &codec_ids) {
            ("sz3", _) => true,
            ("zfp", _) => false,
            (_, Some(ids)) => TileCodec::from_id(ids[i])? == TileCodec::Sz3,
            (_, None) => return Ok(None),
        };
        let b = if use_sz3 {
            Sz3Like::stream_breakdown(&payload[off..off + len], cap)?
        } else {
            ZfpLike::stream_breakdown(&payload[off..off + len], cap)?
        };
        match b.mode {
            "plain" => out.plain += 1,
            "zero-run" => out.zero_run += 1,
            "rans" => {
                out.rans += 1;
                out.rans_lanes = out.rans_lanes.max(b.lanes);
            }
            _ => out.constant += 1,
        }
        out.table_bytes += b.table_bytes;
        out.symbol_bytes += b.symbol_bytes;
        out.aux_bytes += b.aux_bytes;
        out.framing_bytes += b.framing_bytes;
    }
    Ok(Some(out))
}

/// Per-codec tile counts and payload byte shares of a mixed-codec
/// (adaptive) archive — which tiles the selector gave to sz3 vs zfp
/// and how many payload bytes each side holds. `None` for
/// single-codec archives (no per-tile codec-id trailer).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CodecSplit {
    pub sz3_tiles: usize,
    pub sz3_bytes: usize,
    pub zfp_tiles: usize,
    pub zfp_bytes: usize,
}

pub fn codec_split(archive: &Archive, codec: &str) -> Result<Option<CodecSplit>> {
    if codec != "adaptive" || archive.version() == 2 {
        return Ok(None);
    }
    let Some(index) = archive.block_index()? else {
        return Ok(None);
    };
    let Some(ids) = &index.codecs else {
        return Ok(None);
    };
    let Some(dsv) = archive.header.get("dataset") else {
        return Ok(None);
    };
    let Ok(ds) = DatasetConfig::from_json(dsv) else {
        return Ok(None);
    };
    let payload = archive.section("ADPB")?;
    index.validate(&ds.dims, payload.len())?;
    let mut split = CodecSplit::default();
    for (i, &id) in ids.iter().enumerate() {
        let (_, len) = index.entry(i)?;
        match TileCodec::from_id(id)? {
            TileCodec::Sz3 => {
                split.sz3_tiles += 1;
                split.sz3_bytes += len;
            }
            TileCodec::Zfp => {
                split.zfp_tiles += 1;
                split.zfp_bytes += len;
            }
        }
    }
    Ok(Some(split))
}

/// Byte classes of a v4 temporal stream file: step-record payload vs
/// timeline index vs framing (header, record headers, footer, torn
/// tail).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamByteSummary {
    pub codec: String,
    pub file_bytes: usize,
    pub steps: usize,
    pub keyframes: usize,
    pub record_payload_bytes: usize,
    pub tidx_bytes: usize,
    pub framing_bytes: usize,
}

pub fn stream_byte_summary(bytes: &[u8]) -> Result<StreamByteSummary> {
    let (header, start) = parse_stream_header(bytes)?;
    let codec = header
        .get("codec")
        .and_then(|v| v.as_str())
        .unwrap_or("?")
        .to_string();
    // checked streams frame every record with a trailing CRC and carry
    // a header-pinning XSUM record — both count as framing here
    let checked = header.get(XSUM_HEADER_KEY).is_some();
    let rec_overhead = if checked { 12 + 4 } else { 12 };
    let mut off = start;
    let (mut steps, mut keyframes) = (0usize, 0usize);
    let (mut record_payload, mut tidx_bytes) = (0usize, 0usize);
    let mut framing = start;
    while off + rec_overhead <= bytes.len() {
        let parsed = if checked {
            parse_stream_record_checked(bytes, off)
        } else {
            parse_stream_record(bytes, off)
        };
        let Ok((tag, _, len, next)) = parsed else {
            break;
        };
        if tag == *STREAM_KEY_TAG {
            steps += 1;
            keyframes += 1;
            record_payload += len;
        } else if tag == *STREAM_RES_TAG {
            steps += 1;
            record_payload += len;
        } else if tag == *STREAM_TIDX_TAG {
            tidx_bytes += len;
        } else {
            framing += len; // XSUM / unknown records are pure framing
        }
        framing += rec_overhead;
        off = next;
    }
    framing += bytes.len() - off; // footer + any trailing partial record
    Ok(StreamByteSummary {
        codec,
        file_bytes: bytes.len(),
        steps,
        keyframes,
        record_payload_bytes: record_payload,
        tidx_bytes,
        framing_bytes: framing,
    })
}

fn entropy_json(e: &EntropySummary) -> Value {
    json::obj(vec![
        ("tiles", json::num(e.tiles as f64)),
        ("plain", json::num(e.plain as f64)),
        ("zero_run", json::num(e.zero_run as f64)),
        ("const", json::num(e.constant as f64)),
        ("rans", json::num(e.rans as f64)),
        ("rans_lanes", json::num(e.rans_lanes as f64)),
        ("table_bytes", json::num(e.table_bytes as f64)),
        ("symbol_bytes", json::num(e.symbol_bytes as f64)),
        ("aux_bytes", json::num(e.aux_bytes as f64)),
        ("tile_framing_bytes", json::num(e.framing_bytes as f64)),
    ])
}

/// The machine-readable `info` document for an archive or stream file.
pub fn info_json(bytes: &[u8]) -> Result<Value> {
    if bytes.len() >= 4 && &bytes[0..4] == STREAM_MAGIC {
        let s = stream_byte_summary(bytes)?;
        return Ok(json::obj(vec![
            ("kind", json::s("stream")),
            ("version", json::num(4.0)),
            ("codec", json::s(s.codec)),
            ("bytes", json::num(s.file_bytes as f64)),
            ("steps", json::num(s.steps as f64)),
            ("keyframes", json::num(s.keyframes as f64)),
            ("record_payload_bytes", json::num(s.record_payload_bytes as f64)),
            ("tidx_bytes", json::num(s.tidx_bytes as f64)),
            ("framing_bytes", json::num(s.framing_bytes as f64)),
        ]));
    }
    let archive = Archive::from_bytes(bytes)?;
    let codec = archive
        .header
        .get("codec")
        .and_then(|v| v.as_str())
        .unwrap_or("?")
        .to_string();
    let sizes = archive.section_sizes();
    let mut sections_total = 0usize;
    let mut sections = Vec::new();
    for (tag, sz) in &sizes {
        sections.push(json::obj(vec![
            ("tag", json::s(tag.clone())),
            ("bytes", json::num(*sz as f64)),
            ("class", json::s(section_class(tag))),
        ]));
        sections_total += sz;
    }
    let mut pairs = vec![
        ("kind", json::s("archive")),
        ("version", json::num(archive.version() as f64)),
        ("codec", json::s(codec.clone())),
        ("bytes", json::num(bytes.len() as f64)),
        ("sections", Value::Arr(sections)),
    ];
    // v2 expands nested sections, so the framing delta only adds up for
    // single-field containers — same rule as the text renderer
    if archive.version() != 2 {
        pairs.push((
            "framing_bytes",
            json::num(bytes.len().saturating_sub(sections_total) as f64),
        ));
    }
    if let Some(e) = entropy_summary(&archive, &codec)? {
        pairs.push(("entropy", entropy_json(&e)));
    }
    if let Some(cs) = codec_split(&archive, &codec)? {
        pairs.push((
            "tile_codecs",
            json::obj(vec![
                ("sz3_tiles", json::num(cs.sz3_tiles as f64)),
                ("sz3_bytes", json::num(cs.sz3_bytes as f64)),
                ("zfp_tiles", json::num(cs.zfp_tiles as f64)),
                ("zfp_bytes", json::num(cs.zfp_bytes as f64)),
            ]),
        ));
    }
    Ok(json::obj(pairs))
}
