//! Vendored, offline subset of the `anyhow` error-handling crate.
//!
//! The container this repo builds in has no crates.io access, so the
//! surface the codebase actually uses is reimplemented here with the same
//! semantics: a dynamic [`Error`] carrying a context chain, the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!`,
//! `bail!`, `ensure!` macros. Swapping in the real crate is a one-line
//! change in `Cargo.toml`; no call sites need to move.

use std::any::Any;
use std::fmt::{self, Debug, Display};

/// A dynamic error: an outermost message plus the chain of causes.
///
/// `Display` prints the outermost message; `{:#}` (alternate) prints the
/// whole chain joined with `": "`, matching anyhow's formatting that the
/// CLI relies on for `error: {e:#}`.
pub struct Error {
    /// `chain[0]` is the outermost (most recent) context message.
    chain: Vec<String>,
    /// The original root error value, kept for [`Error::downcast_ref`]
    /// (real anyhow supports downcasting; callers like the serve layer
    /// map typed errors such as `Corruption` to specific HTTP codes).
    root: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()], root: None }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Borrow the root cause as a concrete error type, if this error
    /// was built from one (context wrapping preserves it).
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        self.root.as_ref()?.downcast_ref::<E>()
    }

    /// Whether the root cause is of concrete type `E`.
    pub fn is<E: std::error::Error + 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain, root: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e = fails_io().context("opening file").unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        assert_eq!(format!("{e:#}"), "opening file: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(3).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn downcast_ref_reaches_the_root_through_context() {
        let e: Error = fails_io().context("opening file").unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("root preserved");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.is::<std::io::Error>());
        assert!(!Error::msg("plain").is::<std::io::Error>());
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x != 3);
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert!(format!("{}", f(3).unwrap_err()).contains("condition failed"));
        assert_eq!(format!("{:#}", f(200).unwrap_err()), "too big");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.root_cause(), "code 7");
    }
}
