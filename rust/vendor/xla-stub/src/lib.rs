//! Offline stub of the `xla` PJRT bindings used by `attn_reduce::runtime`.
//!
//! The real backend (xla_extension + PJRT CPU client) is a multi-GB C++
//! dependency that is not present in the build container. This crate
//! mirrors exactly the API surface the runtime uses so the whole L3
//! coordinator **compiles and its pure-rust paths run everywhere**; any
//! attempt to actually execute an AOT artifact returns a descriptive
//! error from [`PjRtClient::cpu`]. All artifact-dependent tests and
//! benches already skip when `artifacts/manifest.json` is absent, so a
//! stub build is fully green.
//!
//! To run against real artifacts, patch the `xla` dependency in
//! `rust/Cargo.toml` to the xla_extension bindings (see README.md
//! §Backends); no call sites change.

use std::path::Path;

/// Error type matching the bindings' `{:?}`-printable errors.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: xla backend not available (built with the in-tree xla stub; \
         patch the `xla` dependency to the xla_extension bindings to execute artifacts)"
    )))
}

/// Element dtypes crossing the PJRT boundary (only F32 is used).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Dense array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal (never constructible in the stub).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, XlaError> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper around a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client; `cpu()` is the stub's single point of failure.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("xla backend not available"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}
