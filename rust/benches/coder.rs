//! Entropy-stage throughput (paper §II-E): quantizer, Huffman, index-set
//! codec, LZSS. Run: `cargo bench --bench coder`.

use attn_reduce::coder::{
    decode_index_sets, encode_index_sets, huffman_decode, huffman_encode, indexset,
    lossless_compress, lossless_decompress, Quantizer,
};
use attn_reduce::util::bench::{black_box, Bench};
use attn_reduce::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(7);

    // latent-like data: zero-peaked gaussian codes
    let n = 100_000;
    let latents: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.3) as f32).collect();
    let q = Quantizer::new(0.005);

    b.run_items("quantizer/code 100k f32", n as f64, || {
        black_box(q.codes(black_box(&latents)));
    });

    let codes = q.codes(&latents);
    b.run_items("huffman/encode 100k codes", n as f64, || {
        black_box(huffman_encode(black_box(&codes)));
    });
    let enc = huffman_encode(&codes);
    println!(
        "    (huffman: {} -> {} bytes, {:.2} bits/code)",
        n * 4,
        enc.len(),
        enc.len() as f64 * 8.0 / n as f64
    );
    b.run_items("huffman/decode 100k codes", n as f64, || {
        black_box(huffman_decode(black_box(&enc)).unwrap());
    });

    // GAE-like index sets: leading indices
    let sets: Vec<Vec<usize>> = (0..20_000).map(|i| (0..(i % 9)).collect()).collect();
    b.run_items("indexset/encode 20k sets", sets.len() as f64, || {
        black_box(encode_index_sets(black_box(&sets), 1521).unwrap());
    });
    let ienc = encode_index_sets(&sets, 1521).unwrap();
    b.run_items("indexset/decode 20k sets", sets.len() as f64, || {
        black_box(
            decode_index_sets(black_box(&ienc), indexset::max_raw_size(sets.len(), 1521))
                .unwrap(),
        );
    });

    // lossless LZSS on bitmap-like data
    let bitmap: Vec<u8> = (0..200_000).map(|i| if i % 17 < 2 { 0xFF } else { 0 }).collect();
    b.run_items("lossless/compress 200kB bitmaps", bitmap.len() as f64, || {
        black_box(lossless_compress(black_box(&bitmap)).unwrap());
    });
    let z = lossless_compress(&bitmap).unwrap();
    b.run_items("lossless/decompress", bitmap.len() as f64, || {
        black_box(lossless_decompress(black_box(&z), bitmap.len()).unwrap());
    });

    b.write_csv("results/bench/coder.csv").unwrap();
}
