//! Linalg substrate: covariance accumulation + symmetric eigensolver at
//! the three GAE block sizes. Run: `cargo bench --bench pca`.

use attn_reduce::linalg::{covariance, eigh_symmetric, Pca};
use attn_reduce::util::bench::{black_box, Bench};
use attn_reduce::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(3);
    for &(name, d, rows) in
        &[("d=80", 80usize, 8192usize), ("d=256", 256, 2048), ("d=1521", 1521, 256)]
    {
        let data: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
        b.run_items(&format!("covariance/{name} x{rows}"), (rows * d) as f64, || {
            black_box(covariance(black_box(&data), d));
        });
        let cov = covariance(&data, d);
        if d <= 256 {
            b.run(&format!("eigh/{name}"), || {
                black_box(eigh_symmetric(black_box(&cov), d).unwrap());
            });
        } else {
            // O(d^3): run a single timed shot for the big case
            let t0 = std::time::Instant::now();
            black_box(eigh_symmetric(&cov, d).unwrap());
            println!("eigh/{name}: single shot {:.2}s", t0.elapsed().as_secs_f64());
        }
        let pca = Pca::fit(&data[..rows.min(512) * d], d).unwrap();
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0.0f64; d];
        b.run_items(&format!("pca_project/{name}"), (d * d) as f64, || {
            pca.project(black_box(&x), &mut c);
            black_box(&c);
        });
    }
    b.write_csv("results/bench/pca.csv").unwrap();
}
