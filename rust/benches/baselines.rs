//! Baseline compressor throughput (SZ3-like, ZFP-like) on bench-scale
//! fields — the comparison cost side of Fig. 6.
//! Run: `cargo bench --bench baselines`.

use attn_reduce::baselines::{Sz3Like, ZfpLike};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale};
use attn_reduce::data;
use attn_reduce::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
        let cfg = dataset_preset(kind, Scale::Smoke);
        let field = data::generate(&cfg);
        let bytes_raw = (field.len() * 4) as f64;
        let eps = 1e-3 * field.range();

        b.run_items(&format!("sz3_like/compress {}", kind.name()), bytes_raw, || {
            black_box(Sz3Like::new(eps).compress(black_box(&field)).unwrap());
        });
        let enc = Sz3Like::new(eps).compress(&field).unwrap();
        println!("    (sz3 CR = {:.1})", bytes_raw / enc.len() as f64);
        b.run_items(&format!("sz3_like/decompress {}", kind.name()), bytes_raw, || {
            black_box(Sz3Like::decompress(black_box(&enc)).unwrap());
        });

        b.run_items(&format!("zfp_like/compress {}", kind.name()), bytes_raw, || {
            black_box(ZfpLike::new(12).compress(black_box(&field)).unwrap());
        });
        let zenc = ZfpLike::new(12).compress(&field).unwrap();
        println!("    (zfp CR = {:.1})", bytes_raw / zenc.len() as f64);
        b.run_items(&format!("zfp_like/decompress {}", kind.name()), bytes_raw, || {
            black_box(ZfpLike::decompress(black_box(&zenc)).unwrap());
        });
    }
    b.write_csv("results/bench/baselines.csv").unwrap();
}
