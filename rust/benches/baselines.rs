//! Baseline compressor throughput (SZ3-like, ZFP-like) on bench-scale
//! fields, constructed through the unified `CodecBuilder` — the
//! comparison cost side of Fig. 6.
//! Run: `cargo bench --bench baselines`.

use attn_reduce::codec::{Codec, CodecBuilder, CodecKind, ErrorBound};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale};
use attn_reduce::data;
use attn_reduce::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    let mut builder = CodecBuilder::new().scale(Scale::Smoke);
    for kind in [DatasetKind::S3d, DatasetKind::E3sm, DatasetKind::Xgc] {
        let cfg = dataset_preset(kind, Scale::Smoke);
        let field = data::generate(&cfg);
        let bytes_raw = (field.len() * 4) as f64;
        // pointwise bound = direct eps, so the sz3 numbers measure the
        // compressor, not a search
        let sz3_bound = ErrorBound::PointwiseAbs((1e-3 * field.range()) as f64);

        let sz3 = builder.build(CodecKind::Sz3, kind, &field).unwrap();
        b.run_items(&format!("sz3_like/compress {}", kind.name()), bytes_raw, || {
            black_box(sz3.compress(black_box(&field), &sz3_bound).unwrap());
        });
        let enc = sz3.compress(&field, &sz3_bound).unwrap();
        println!("    (sz3 CR = {:.1})", bytes_raw / enc.total_bytes() as f64);
        b.run_items(&format!("sz3_like/decompress {}", kind.name()), bytes_raw, || {
            black_box(sz3.decompress(black_box(&enc)).unwrap());
        });

        // ErrorBound::None = the fixed default precision (no search)
        let zfp = builder.build(CodecKind::Zfp, kind, &field).unwrap();
        b.run_items(&format!("zfp_like/compress {}", kind.name()), bytes_raw, || {
            black_box(zfp.compress(black_box(&field), &ErrorBound::None).unwrap());
        });
        let zenc = zfp.compress(&field, &ErrorBound::None).unwrap();
        println!("    (zfp CR = {:.1})", bytes_raw / zenc.total_bytes() as f64);
        b.run_items(&format!("zfp_like/decompress {}", kind.name()), bytes_raw, || {
            black_box(zfp.decompress(black_box(&zenc)).unwrap());
        });
    }
    b.write_csv("results/bench/baselines.csv").unwrap();
}
