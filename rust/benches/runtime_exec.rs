//! PJRT execution latency per AOT entry point — the L3 hot-path unit
//! costs (encode / decode / fused pipe / train_step).
//! Run: `cargo bench --bench runtime_exec` (needs `make artifacts`).

use attn_reduce::runtime::{HostTensor, Runtime};
use attn_reduce::util::bench::{black_box, Bench};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        println!("skipping: run `make artifacts` first");
        return;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    let rt = Runtime::open(dir).unwrap();
    let mut b = Bench::new();

    let hg = "s3d_hbae_L128";
    let bg = "s3d_bae_L16";
    let pg = "s3d_pipe_L128_16";

    let theta = rt.load(hg, "init").unwrap().run(&[]).unwrap().remove(0);
    let phi = rt.load(bg, "init").unwrap().run(&[]).unwrap().remove(0);

    let enc = rt.load(hg, "encode").unwrap();
    let bsig = enc.info.inputs[1].clone();
    let batch = HostTensor::new(
        bsig.shape.clone(),
        (0..bsig.len()).map(|i| ((i % 101) as f32 / 101.0 - 0.5)).collect(),
    );
    let elems = bsig.len() as f64;

    b.run_items("hbae/encode [32,10,1280]", elems, || {
        black_box(enc.run(&[theta.clone(), batch.clone()]).unwrap());
    });
    let lat = enc.run(&[theta.clone(), batch.clone()]).unwrap().remove(0);
    let dec = rt.load(hg, "decode").unwrap();
    b.run_items("hbae/decode", elems, || {
        black_box(dec.run(&[theta.clone(), lat.clone()]).unwrap());
    });

    let benc = rt.load(bg, "encode").unwrap();
    let rsig = benc.info.inputs[1].clone();
    let resid = HostTensor::new(
        rsig.shape.clone(),
        (0..rsig.len()).map(|i| ((i % 89) as f32 / 890.0)).collect(),
    );
    b.run_items("bae/encode [320,1280]", elems, || {
        black_box(benc.run(&[phi.clone(), resid.clone()]).unwrap());
    });

    let fwd = rt.load(pg, "forward").unwrap();
    let zero = HostTensor::scalar(0.005);
    b.run_items("pipe/forward (fused)", elems, || {
        black_box(
            fwd.run(&[theta.clone(), phi.clone(), batch.clone(), zero.clone(), zero.clone()])
                .unwrap(),
        );
    });

    let step = rt.load(bg, "train_step").unwrap();
    let pdim = rt.param_dim(bg).unwrap();
    let m = HostTensor::vec(vec![0.0; pdim]);
    let v = HostTensor::vec(vec![0.0; pdim]);
    let t = HostTensor::scalar(0.0);
    let lr = HostTensor::scalar(1e-3);
    b.run_items("bae/train_step [320,1280]", elems, || {
        black_box(
            step.run(&[
                phi.clone(),
                m.clone(),
                v.clone(),
                t.clone(),
                lr.clone(),
                resid.clone(),
            ])
            .unwrap(),
        );
    });

    let hstep = rt.load(hg, "train_step").unwrap();
    let hdim = rt.param_dim(hg).unwrap();
    let hm = HostTensor::vec(vec![0.0; hdim]);
    let hv = HostTensor::vec(vec![0.0; hdim]);
    b.run_items("hbae/train_step [32,10,1280]", elems, || {
        black_box(
            hstep
                .run(&[
                    theta.clone(),
                    hm.clone(),
                    hv.clone(),
                    t.clone(),
                    lr.clone(),
                    batch.clone(),
                ])
                .unwrap(),
        );
    });

    b.write_csv("results/bench/runtime_exec.csv").unwrap();
}
