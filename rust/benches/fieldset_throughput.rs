//! Dataset-level (FieldSet) compression throughput: serial seed vs the
//! block-parallel executor, per codec, on a synthetic multi-species S3D
//! set. Emits `BENCH_fieldset.json` (MB/s, CR, speedup) next to the CWD.
//!
//! Run: `cargo bench --bench fieldset_throughput`
//! (`BENCH_FAST=1` shrinks to smoke scale for CI.)

use attn_reduce::codec::{archive_stats, Codec, ErrorBound, Sz3Codec, ZfpCodec};
use attn_reduce::config::{DatasetKind, Scale};
use attn_reduce::engine::{compress_set_parallel, CodecExt, FieldSet};
use attn_reduce::util::bench::median_secs;
use attn_reduce::util::json::{self, Value};
use attn_reduce::util::parallel::{num_threads, with_thread_limit};

fn bench_codec<C: Codec + Sync>(
    name: &str,
    codec: &C,
    set: &FieldSet,
    bound: &ErrorBound,
    iters: usize,
) -> Value {
    let raw_mb = set.raw_bytes() as f64 / 1e6;
    // serial seed: whole pipeline forced to one thread
    let serial_s = median_secs(
        || {
            with_thread_limit(1, || {
                codec.compress_set(set, bound).expect("serial compress_set");
            });
        },
        iters,
    );
    // block-parallel engine: per-field jobs + per-block work items
    let parallel_s = median_secs(
        || {
            compress_set_parallel(codec, set, bound).expect("parallel compress_set");
        },
        iters,
    );
    let archive = compress_set_parallel(codec, set, bound).unwrap();
    let stats = archive_stats(&archive).expect("archive stats");
    let speedup = serial_s / parallel_s.max(1e-12);
    println!(
        "{name:>4}: serial {:>8.2} MB/s | parallel {:>8.2} MB/s | speedup {speedup:>5.2}x | CR {:.1}",
        raw_mb / serial_s,
        raw_mb / parallel_s,
        stats.cr
    );
    json::obj(vec![
        ("codec", json::s(name)),
        ("raw_mb", json::num(raw_mb)),
        ("serial_s", json::num(serial_s)),
        ("parallel_s", json::num(parallel_s)),
        ("mb_s_serial", json::num(raw_mb / serial_s)),
        ("mb_s_parallel", json::num(raw_mb / parallel_s)),
        ("speedup", json::num(speedup)),
        ("cr_payload", json::num(stats.cr)),
        ("cr_total", json::num(stats.cr_total)),
        ("archive_bytes", json::num(stats.archive_bytes as f64)),
    ])
}

fn main() {
    let fast = std::env::var_os("BENCH_FAST").is_some();
    let (scale, n_vars, iters) = if fast {
        (Scale::Smoke, 4, 2)
    } else {
        (Scale::Bench, 4, 3)
    };
    let set = FieldSet::generate(DatasetKind::S3d, scale, n_vars);
    println!(
        "fieldset: s3d x {n_vars} vars, {:.1} MB raw, {} threads",
        set.raw_bytes() as f64 / 1e6,
        num_threads()
    );
    // closed-form bounds only, so the numbers measure the compressors,
    // not the zfp precision search
    let sz3 = bench_codec(
        "sz3",
        &Sz3Codec::new(set.dataset().clone()),
        &set,
        &ErrorBound::Nrmse(1e-3),
        iters,
    );
    let zfp = bench_codec(
        "zfp",
        &ZfpCodec::new(set.dataset().clone()),
        &set,
        &ErrorBound::None,
        iters,
    );
    let report = json::obj(vec![
        ("dataset", json::s("s3d")),
        ("scale", json::s(if fast { "smoke" } else { "bench" })),
        ("n_vars", json::num(n_vars as f64)),
        ("threads", json::num(num_threads() as f64)),
        ("codecs", Value::Arr(vec![sz3, zfp])),
    ]);
    std::fs::write("BENCH_fieldset.json", report.to_string_pretty())
        .expect("write BENCH_fieldset.json");
    println!("wrote BENCH_fieldset.json");
}
