//! Region-of-interest decode vs full decode on Archive v3 block-indexed
//! containers: wall-clock MB/s and payload bytes touched, per pure-rust
//! codec. Emits `BENCH_region.json` next to the CWD.
//!
//! Run: `cargo bench --bench region_decode`
//! (`--smoke` or `BENCH_FAST=1` shrinks to smoke scale for CI.)

use attn_reduce::codec::{AdaptiveCodec, Codec, ErrorBound, Sz3Codec, ZfpCodec};
use attn_reduce::compressor::Archive;
use attn_reduce::config::{dataset_preset, DatasetKind, Scale};
use attn_reduce::data::{self, region_tile_ids, Region};
use attn_reduce::util::bench::median_secs;
use attn_reduce::util::json::{self, Value};
use attn_reduce::util::parallel::num_threads;

fn bench_codec<C: Codec>(
    name: &str,
    codec: &C,
    field: &attn_reduce::tensor::Tensor,
    bound: &ErrorBound,
    region: &Region,
    iters: usize,
) -> Value {
    let archive = codec.compress(field, bound).expect("compress");
    // decode from reparsed bytes, like a cold consumer would
    let archive = Archive::from_bytes(&archive.to_bytes()).expect("reparse");
    let index = archive.block_index().expect("index parses").expect("v3 archive");
    let dims = field.shape();
    let ids = region_tile_ids(dims, &index.tile, region);
    let n_tiles = index.entries.len();
    let payload_bytes = index.total_bytes();
    let bytes_touched = index.bytes_for(&ids);

    let full_s = median_secs(|| drop(codec.decompress(&archive).expect("full")), iters);
    let region_s = median_secs(
        || drop(codec.decompress_region(&archive, region).expect("region")),
        iters,
    );
    let raw_mb = (field.len() * 4) as f64 / 1e6;
    let region_mb = (region.n_points() * 4) as f64 / 1e6;
    let speedup = full_s / region_s.max(1e-12);
    println!(
        "{name:>4}: full {:>8.2} MB/s | region {:>8.2} MB/s (of region bytes) | \
         speedup {speedup:>5.2}x | blocks {}/{} | bytes {}/{} ({:.1}%)",
        raw_mb / full_s,
        region_mb / region_s,
        ids.len(),
        n_tiles,
        bytes_touched,
        payload_bytes,
        100.0 * bytes_touched as f64 / payload_bytes.max(1) as f64,
    );
    let mut entry = vec![
        ("codec", json::s(name)),
        ("raw_mb", json::num(raw_mb)),
        ("region_mb", json::num(region_mb)),
        ("full_s", json::num(full_s)),
        ("region_s", json::num(region_s)),
        ("mb_s_full", json::num(raw_mb / full_s)),
        ("mb_s_region", json::num(region_mb / region_s)),
        ("speedup", json::num(speedup)),
        ("blocks_total", json::num(n_tiles as f64)),
        ("blocks_touched", json::num(ids.len() as f64)),
        ("payload_bytes", json::num(payload_bytes as f64)),
        ("bytes_touched", json::num(bytes_touched as f64)),
        (
            "frac_bytes_touched",
            json::num(bytes_touched as f64 / payload_bytes.max(1) as f64),
        ),
    ];
    // mixed-codec archives (the adaptive leg) also report their split
    if let Some(cids) = &index.codecs {
        let (mut st, mut sb, mut zt, mut zb) = (0u64, 0u64, 0u64, 0u64);
        for (&(_, len), &id) in index.entries.iter().zip(cids) {
            if id == 0 {
                st += 1;
                sb += len;
            } else {
                zt += 1;
                zb += len;
            }
        }
        entry.push(("sz3_tiles", json::num(st as f64)));
        entry.push(("sz3_bytes", json::num(sb as f64)));
        entry.push(("zfp_tiles", json::num(zt as f64)));
        entry.push(("zfp_bytes", json::num(zb as f64)));
    }
    json::obj(entry)
}

fn main() {
    let smoke = std::env::var_os("BENCH_FAST").is_some()
        || std::env::args().any(|a| a == "--smoke");
    let (scale, iters) = if smoke { (Scale::Smoke, 2) } else { (Scale::Bench, 5) };
    let cfg = dataset_preset(DatasetKind::E3sm, scale);
    let field = data::generate(&cfg);
    // a corner region of ~1/4 extent per axis: a handful of blocks on a
    // mesh of hundreds (the post-hoc analysis / visualization workload)
    let region = Region::new(
        vec![0; cfg.dims.len()],
        cfg.dims.iter().map(|&d| (d / 4).max(1)).collect(),
    )
    .expect("region");
    println!(
        "region_decode: e3sm {:?}, region {:?}, {} threads",
        cfg.dims,
        region.shape(),
        num_threads()
    );
    let sz3 = bench_codec(
        "sz3",
        &Sz3Codec::new(cfg.clone()),
        &field,
        &ErrorBound::Nrmse(1e-3),
        &region,
        iters,
    );
    // `None` keeps the zfp numbers about decode, not the precision search
    let zfp = bench_codec(
        "zfp",
        &ZfpCodec::new(cfg.clone()),
        &field,
        &ErrorBound::None,
        &region,
        iters,
    );
    // the adaptive leg decodes a mixed-codec archive: the per-tile
    // dispatch overhead shows up against the single-codec baselines
    let adaptive = bench_codec(
        "adaptive",
        &AdaptiveCodec::new(cfg.clone()),
        &field,
        &ErrorBound::Nrmse(1e-3),
        &region,
        iters,
    );
    let report = json::obj(vec![
        ("dataset", json::s("e3sm")),
        ("scale", json::s(if smoke { "smoke" } else { "bench" })),
        ("dims", json::arr_usize(&cfg.dims)),
        ("region_lo", json::arr_usize(&region.lo)),
        ("region_hi", json::arr_usize(&region.hi)),
        ("threads", json::num(num_threads() as f64)),
        ("codecs", Value::Arr(vec![sz3, zfp, adaptive])),
    ]);
    std::fs::write("BENCH_region.json", report.to_string_pretty())
        .expect("write BENCH_region.json");
    println!("wrote BENCH_region.json");
}
