//! Temporal stream throughput: append MB/s, compression ratio vs
//! independent-per-step v3 archives at the same error bound, and
//! `(step, region)` random-access latency as a function of the keyframe
//! interval K. Emits `BENCH_stream.json` next to the CWD.
//!
//! Run: `cargo bench --bench stream_throughput`
//! (`--smoke` or `BENCH_FAST=1` shrinks to smoke scale for CI.)

use attn_reduce::codec::{Codec, ErrorBound, Sz3Codec};
use attn_reduce::config::{stream_frame_preset, DatasetKind, Scale};
use attn_reduce::data::{timeseries, Region};
use attn_reduce::stream::{StreamReader, StreamWriter};
use attn_reduce::util::bench::median_secs;
use attn_reduce::util::json::{self, Value};
use attn_reduce::util::parallel::num_threads;

fn main() {
    let smoke = std::env::var_os("BENCH_FAST").is_some()
        || std::env::args().any(|a| a == "--smoke");
    let (scale, steps, iters) = if smoke {
        (Scale::Smoke, 16usize, 2usize)
    } else {
        (Scale::Bench, 64, 5)
    };
    let cfg = stream_frame_preset(DatasetKind::E3sm, scale);
    let bound = ErrorBound::Nrmse(1e-3);
    let codec = Sz3Codec::new(cfg.clone());
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, steps);
    let raw_mb = (steps * cfg.total_points() * 4) as f64 / 1e6;
    let dir = std::env::temp_dir().join("attn_reduce_stream_bench");
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    println!(
        "stream_throughput: e3sm frames {:?} x {steps} steps, bound {bound}, {} threads",
        cfg.dims,
        num_threads()
    );

    // baseline: every step an independent v3 archive (what the engine
    // did before the stream subsystem existed)
    let independent_payload: usize = frames
        .iter()
        .map(|f| codec.compress(f, &bound).expect("compress").cr_payload_bytes())
        .sum();
    let n_points = steps * cfg.total_points();
    let cr_independent = n_points as f64 / independent_payload.max(1) as f64;
    println!(
        "independent per-step archives: payload {independent_payload} bytes, CR {cr_independent:.1}"
    );

    // a corner region of ~1/4 extent per axis, read at the worst-case
    // step of a GOP (longest residual chain)
    let region = Region::new(
        vec![0; cfg.dims.len()],
        cfg.dims.iter().map(|&d| (d / 4).max(1)).collect(),
    )
    .expect("region");

    let mut per_k = Vec::new();
    for k in [1usize, 4, 8, 16] {
        let path = dir.join(format!("bench_k{k}.tstr"));
        let append_s = median_secs(
            || {
                let mut w =
                    StreamWriter::create(&path, codec.id(), cfg.clone(), bound, k)
                        .expect("create stream");
                w.append_frames(&codec, &frames).expect("append");
                w.finish().expect("finish");
            },
            iters,
        );
        let reader = StreamReader::open(&path).expect("open stream");
        let stats = reader.stats().expect("stats");
        // worst-case chain: the final step (step counts divide every K
        // here, so its chain has the full K-step length)
        let step = steps - 1;
        let cost = reader.region_cost(step, &region).expect("cost");
        let extract_s = median_secs(
            || drop(reader.extract(&codec, step, &region).expect("extract")),
            iters,
        );
        let frame_s = median_secs(
            || drop(reader.frame(&codec, step).expect("frame")),
            iters,
        );
        let cr_ratio = stats.cr / cr_independent;
        println!(
            "K={k:>2}: append {:>7.2} MB/s | CR {:>6.1} ({cr_ratio:>4.2}x vs independent) | \
             extract(step {step}, region) {:>8.3} ms over {} chain steps | full frame {:>8.3} ms",
            raw_mb / append_s,
            stats.cr,
            extract_s * 1e3,
            cost.steps,
            frame_s * 1e3,
        );
        per_k.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("append_s", json::num(append_s)),
            ("append_mb_s", json::num(raw_mb / append_s)),
            ("payload_bytes", json::num(stats.payload_bytes as f64)),
            ("file_bytes", json::num(stats.file_bytes as f64)),
            ("cr", json::num(stats.cr)),
            ("cr_vs_independent", json::num(cr_ratio)),
            ("extract_step", json::num(step as f64)),
            ("chain_steps", json::num(cost.steps as f64)),
            ("region_bytes_touched", json::num(cost.bytes_touched as f64)),
            ("region_bytes_total", json::num(cost.bytes_total as f64)),
            ("extract_region_s", json::num(extract_s)),
            ("extract_frame_s", json::num(frame_s)),
        ]));
    }

    let report = json::obj(vec![
        ("dataset", json::s("e3sm")),
        ("scale", json::s(if smoke { "smoke" } else { "bench" })),
        ("dims", json::arr_usize(&cfg.dims)),
        ("steps", json::num(steps as f64)),
        ("bound", json::s(bound.to_string())),
        ("threads", json::num(num_threads() as f64)),
        ("raw_mb", json::num(raw_mb)),
        ("independent_payload_bytes", json::num(independent_payload as f64)),
        ("cr_independent", json::num(cr_independent)),
        ("ks", Value::Arr(per_k)),
    ]);
    std::fs::write("BENCH_stream.json", report.to_string_pretty())
        .expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
    std::fs::remove_dir_all(&dir).ok();
}
