//! GAE stage cost (Algorithm 1): PCA fit + per-block correction, at each
//! dataset's GAE block geometry. Run: `cargo bench --bench gae`.

use attn_reduce::compressor::gae_apply;
use attn_reduce::util::bench::{black_box, Bench};
use attn_reduce::util::rng::Rng;

fn make_case(n_blocks: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let rank = 4;
    let dirs: Vec<f64> = (0..rank * d).map(|_| rng.normal()).collect();
    let mut orig = vec![0f32; n_blocks * d];
    let mut recon = vec![0f32; n_blocks * d];
    for b in 0..n_blocks {
        for i in 0..d {
            recon[b * d + i] = rng.normal() as f32;
        }
        for k in 0..rank {
            let w = rng.normal() / (k + 1) as f64;
            for i in 0..d {
                orig[b * d + i] = recon[b * d + i] + (w * dirs[k * d + i]) as f32;
            }
        }
    }
    (orig, recon)
}

fn main() {
    let mut b = Bench::new();
    // geometries: S3D 5x4x4=80, E3SM 16x16=256, XGC 39x39=1521
    for &(name, d, n_blocks, tau) in &[
        ("s3d d=80", 80usize, 4096usize, 0.6f32),
        ("e3sm d=256", 256, 1024, 1.2),
        ("xgc d=1521", 1521, 128, 3.0),
    ] {
        let (orig, recon0) = make_case(n_blocks, d, 42);
        b.run_items(
            &format!("gae_apply/{name} x{n_blocks} blocks"),
            (n_blocks * d) as f64,
            || {
                let mut recon = recon0.clone();
                let taus = vec![tau; n_blocks];
                black_box(gae_apply(black_box(&orig), &mut recon, d, &taus).unwrap());
            },
        );
    }
    b.write_csv("results/bench/gae.csv").unwrap();
}
