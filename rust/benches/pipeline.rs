//! End-to-end pipeline throughput through the unified codec: sequential
//! vs streaming coordinator at several queue depths, plus full compress
//! (with GAE) and header-driven decompress on a smoke field.
//! Run: `cargo bench --bench pipeline` (needs `make artifacts`; trains a
//! small model on first run, cached under results/ckpt-bench).

use std::rc::Rc;

use attn_reduce::codec::{Codec, CodecBuilder, ErrorBound};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::runtime::Runtime;
use attn_reduce::util::bench::{black_box, Bench};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        println!("skipping: run `make artifacts` first");
        return;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    let rt = Rc::new(Runtime::open(dir).unwrap());
    let mut b = Bench::new();

    let dataset = dataset_preset(DatasetKind::S3d, Scale::Smoke);
    let field = data::generate(&dataset);
    let bytes = (field.len() * 4) as f64;
    let mut builder = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Smoke)
        .ckpt_dir("results/ckpt-bench")
        .train(TrainConfig { steps: 40, log_every: 1000, ..TrainConfig::default() });
    let codec = builder.build_hier(DatasetKind::S3d, &field).unwrap();

    // sequential AE pass (no GAE) vs streaming at queue depths
    b.run_items("pipeline/sequential compress (no GAE)", bytes, || {
        black_box(codec.compress(black_box(&field), &ErrorBound::None).unwrap());
    });
    for depth in [0usize, 2, 8] {
        b.run_items(&format!("pipeline/stream q={depth}"), bytes, || {
            black_box(
                codec
                    .compress_streaming(black_box(&field), &ErrorBound::None, depth)
                    .unwrap(),
            );
        });
    }

    // full compress incl. GAE + entropy under a typed bound
    let bound = ErrorBound::Nrmse(1e-3);
    b.run_items("pipeline/full compress (GAE @nrmse 1e-3)", bytes, || {
        black_box(codec.compress(black_box(&field), &bound).unwrap());
    });

    // decompression through the trait surface
    let archive = codec.compress(&field, &bound).unwrap();
    b.run_items("pipeline/decompress", bytes, || {
        black_box(codec.decompress(black_box(&archive)).unwrap());
    });

    b.write_csv("results/bench/pipeline.csv").unwrap();
}
