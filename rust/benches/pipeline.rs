//! End-to-end pipeline throughput: sequential vs streaming coordinator at
//! several queue depths, plus full compress (with GAE) on a smoke field.
//! Run: `cargo bench --bench pipeline` (needs `make artifacts`; trains a
//! small model on first run, cached under results/ckpt-bench).

use attn_reduce::compressor::HierCompressor;
use attn_reduce::config::{dataset_preset, model_preset, DatasetKind, PipelineConfig, Scale};
use attn_reduce::coordinator::stream_compress;
use attn_reduce::data::{self, Normalizer};
use attn_reduce::runtime::Runtime;
use attn_reduce::util::bench::{black_box, Bench};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        println!("skipping: run `make artifacts` first");
        return;
    }
    std::env::set_var("ATTN_REDUCE_QUIET", "1");
    let rt = Runtime::open(dir).unwrap();
    let mut b = Bench::new();

    let mut cfg = PipelineConfig {
        dataset: dataset_preset(DatasetKind::S3d, Scale::Smoke),
        model: model_preset(DatasetKind::S3d),
        train: Default::default(),
        tau: 0.0,
    };
    cfg.train.steps = 40;
    cfg.train.log_every = 1000;
    let field = data::generate(&cfg.dataset);
    let ckpt = std::path::PathBuf::from("results/ckpt-bench");
    std::fs::create_dir_all(&ckpt).unwrap();
    let (comp, _) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field).unwrap();
    let bytes = (field.len() * 4) as f64;

    let stats = Normalizer::fit(cfg.dataset.normalization, &field);
    let mut norm = field.clone();
    Normalizer::apply(&stats, &mut norm);

    // sequential AE pass (tau=0: no GAE) vs streaming at queue depths
    b.run_items("pipeline/sequential compress (no GAE)", bytes, || {
        black_box(comp.compress(black_box(&field), 0.0).unwrap());
    });
    for depth in [0usize, 2, 8] {
        b.run_items(&format!("pipeline/stream q={depth}"), bytes, || {
            black_box(stream_compress(&comp, black_box(&field), depth).unwrap());
        });
    }

    // full compress incl. GAE + entropy
    let tau = PipelineConfig::tau_for_nrmse(
        1e-3,
        field.range() as f64,
        cfg.dataset.gae_block_len(),
    );
    b.run_items("pipeline/full compress (GAE @1e-3)", bytes, || {
        black_box(comp.compress(black_box(&field), tau).unwrap());
    });

    // decompression
    let (archive, _) = comp.compress(&field, tau).unwrap();
    b.run_items("pipeline/decompress", bytes, || {
        black_box(
            HierCompressor::decompress(&rt, black_box(&archive), &comp.hbae, &comp.baes)
                .unwrap(),
        );
    });

    b.write_csv("results/bench/pipeline.csv").unwrap();
}
