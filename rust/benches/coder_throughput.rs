//! Entropy-coder hot-path throughput: Huffman encode/decode MB/s (LUT
//! decoder vs the bit-at-a-time oracle), interleaved rANS vs LUT-Huffman
//! on a dense near-gaussian stream (MB/s + bytes at matched content),
//! symbol-container sizes on a zero-peaked residual-shaped stream, and
//! residual GOP payload bytes / CR at equal bound with the zero-run
//! modes on vs forced off (the PR-4 plain framing). Emits
//! `BENCH_coder.json` so this and future perf PRs have a pinned
//! trajectory.
//!
//! Run: `cargo bench --bench coder_throughput`
//! (`--smoke` or `BENCH_FAST=1` shrinks the workload for CI.)

use attn_reduce::codec::{Codec, ErrorBound, Sz3Codec};
use attn_reduce::coder::{
    compress_symbols_mode, decompress_symbols, huffman_decode, huffman_decode_bitwise,
    huffman_encode, rans_decode_into, rans_encode, with_symbol_mode, RansScratch, SymbolMode,
};
use attn_reduce::config::{stream_frame_preset, DatasetKind, Scale};
use attn_reduce::data::timeseries;
use attn_reduce::obs;
use attn_reduce::stream::StreamWriter;
use attn_reduce::tensor::Tensor;
use attn_reduce::util::bench::median_secs;
use attn_reduce::util::json;
use attn_reduce::util::parallel::{num_threads, with_thread_limit};
use attn_reduce::util::rng::Rng;

/// Residual GOP write with the symbol mode optionally forced; returns
/// (residual payload bytes, total payload bytes).
fn stream_payload(
    frames: &[Tensor],
    cfg: &attn_reduce::config::DatasetConfig,
    keyint: usize,
    mode: Option<SymbolMode>,
    path: &std::path::Path,
) -> (usize, usize) {
    let codec = Sz3Codec::new(cfg.clone());
    let bound = ErrorBound::Nrmse(1e-3);
    with_thread_limit(1, || {
        let run = || {
            std::fs::remove_file(path).ok();
            let mut w =
                StreamWriter::create(path, codec.id(), cfg.clone(), bound, keyint)
                    .expect("create stream");
            let stats = w.append_frames(&codec, frames).expect("append");
            w.finish().expect("finish");
            let residual: usize = stats
                .iter()
                .filter(|s| !s.keyframe)
                .map(|s| s.payload_bytes)
                .sum();
            let total: usize = stats.iter().map(|s| s.payload_bytes).sum();
            (residual, total)
        };
        match mode {
            Some(m) => with_symbol_mode(m, run),
            None => run(),
        }
    })
}

/// Min-of-N wall time: the right statistic for an overhead ratio — the
/// minimum sheds scheduler noise that would otherwise dwarf a 2% bound.
fn min_secs(mut f: impl FnMut(), iters: usize) -> f64 {
    f(); // warmup
    (0..iters)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    let smoke = std::env::var_os("BENCH_FAST").is_some()
        || std::env::args().any(|a| a == "--smoke");
    let (n_syms, steps, iters) = if smoke {
        (200_000usize, 8usize, 2usize)
    } else {
        (2_000_000, 32, 5)
    };

    // zero-peaked residual-shaped quantized codes (~92% zeros)
    let mut rng = Rng::new(17);
    let codes: Vec<i32> = (0..n_syms)
        .map(|_| if rng.below(12) == 0 { (rng.below(7) as i32) - 3 } else { 0 })
        .collect();
    let raw_mb = (n_syms * 4) as f64 / 1e6;
    println!(
        "coder_throughput: {n_syms} zero-peaked symbols ({raw_mb:.1} MB raw), {} threads",
        num_threads()
    );

    let enc_s = median_secs(
        || {
            std::hint::black_box(huffman_encode(std::hint::black_box(&codes)));
        },
        iters,
    );
    let enc = huffman_encode(&codes);
    let dec_s = median_secs(
        || {
            std::hint::black_box(huffman_decode(std::hint::black_box(&enc)).unwrap());
        },
        iters,
    );
    let dec_bitwise_s = median_secs(
        || {
            std::hint::black_box(huffman_decode_bitwise(std::hint::black_box(&enc)).unwrap());
        },
        iters,
    );
    println!(
        "huffman: encode {:7.1} MB/s | decode {:7.1} MB/s (LUT) vs {:7.1} MB/s (bitwise) \
         -> {:.2}x",
        raw_mb / enc_s,
        raw_mb / dec_s,
        raw_mb / dec_bitwise_s,
        dec_bitwise_s / dec_s
    );

    // dense near-gaussian stream — the shape the interleaved rANS mode
    // targets (hundreds of distinct symbols, no dominant value), coded
    // head-to-head against raw LUT-Huffman on the same content
    let mut rng = Rng::new(23);
    let dense: Vec<i32> = (0..n_syms).map(|_| (rng.normal() * 40.0).round() as i32).collect();
    let dense_huff = huffman_encode(&dense);
    let dense_huff_dec_s = median_secs(
        || {
            std::hint::black_box(huffman_decode(std::hint::black_box(&dense_huff)).unwrap());
        },
        iters,
    );
    let rans_enc_s = median_secs(
        || {
            std::hint::black_box(rans_encode(std::hint::black_box(&dense)).unwrap());
        },
        iters,
    );
    let dense_rans = rans_encode(&dense).expect("rans encode");
    let mut rans_scratch = RansScratch::default();
    let mut rans_out = Vec::new();
    let rans_dec_s = median_secs(
        || {
            rans_decode_into(
                std::hint::black_box(&dense_rans),
                dense.len(),
                &mut rans_out,
                &mut rans_scratch,
            )
            .unwrap();
            std::hint::black_box(rans_out.len());
        },
        iters,
    );
    let rans_speedup = dense_huff_dec_s / rans_dec_s;
    println!(
        "rans (dense): encode {:7.1} MB/s | decode {:7.1} MB/s vs huffman LUT {:7.1} MB/s \
         -> {:.2}x | {} B vs {} B huffman",
        raw_mb / rans_enc_s,
        raw_mb / rans_dec_s,
        raw_mb / dense_huff_dec_s,
        rans_speedup,
        dense_rans.len(),
        dense_huff.len()
    );

    let plain = compress_symbols_mode(&codes, SymbolMode::Plain).expect("plain");
    let zrun = compress_symbols_mode(&codes, SymbolMode::ZeroRun).expect("zero-run");
    let zrun_dec_s = median_secs(
        || {
            std::hint::black_box(
                decompress_symbols(std::hint::black_box(&zrun), codes.len()).unwrap(),
            );
        },
        iters,
    );
    println!(
        "container: plain {} B vs zero-run {} B ({:.1}% smaller) | zero-run decode {:7.1} MB/s",
        plain.len(),
        zrun.len(),
        100.0 * (1.0 - zrun.len() as f64 / plain.len() as f64),
        raw_mb / zrun_dec_s
    );

    // observability overhead: the identical dense rANS container decode
    // with the span/counter instrumentation live (the production
    // default) vs the kill switch. The pinned budget is ≤2% on the full
    // run; smoke runs keep a looser guard because sub-ms timings on
    // shared CI runners carry more scheduler noise than the budget.
    let dense_cont = compress_symbols_mode(&dense, SymbolMode::Rans).expect("rans container");
    let obs_iters = (iters * 3).max(9);
    obs::trace::set_enabled(false);
    let off_s = min_secs(
        || {
            std::hint::black_box(
                decompress_symbols(std::hint::black_box(&dense_cont), dense.len()).unwrap(),
            );
        },
        obs_iters,
    );
    obs::trace::set_enabled(true);
    let on_s = min_secs(
        || {
            std::hint::black_box(
                decompress_symbols(std::hint::black_box(&dense_cont), dense.len()).unwrap(),
            );
        },
        obs_iters,
    );
    let obs_ratio = on_s / off_s;
    let obs_budget = if smoke { 1.15 } else { 1.02 };
    println!(
        "obs overhead (dense container decode): {:7.1} MB/s off -> {:7.1} MB/s on \
         ({:+.2}% | budget {:.0}%)",
        raw_mb / off_s,
        raw_mb / on_s,
        100.0 * (obs_ratio - 1.0),
        100.0 * (obs_budget - 1.0)
    );
    assert!(
        obs_ratio <= obs_budget,
        "span/counter overhead {:.2}% blew the {:.0}% budget",
        100.0 * (obs_ratio - 1.0),
        100.0 * (obs_budget - 1.0)
    );

    // residual GOPs at equal bound: auto modes vs the PR-4 plain framing.
    // One tile per frame so the entropy stage dominates the payload.
    let mut cfg = stream_frame_preset(
        DatasetKind::E3sm,
        if smoke { Scale::Smoke } else { Scale::Bench },
    );
    cfg.ae_block = cfg.dims.clone();
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, steps);
    let n_points = steps * cfg.total_points();
    let dir = std::env::temp_dir().join("attn_reduce_coder_bench");
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    let keyint = 8usize;
    let (res_plain, tot_plain) = stream_payload(
        &frames,
        &cfg,
        keyint,
        Some(SymbolMode::Plain),
        &dir.join("plain.tstr"),
    );
    let (res_auto, tot_auto) =
        stream_payload(&frames, &cfg, keyint, None, &dir.join("auto.tstr"));
    let cr_plain = n_points as f64 / tot_plain.max(1) as f64;
    let cr_auto = n_points as f64 / tot_auto.max(1) as f64;
    let saving = 1.0 - res_auto as f64 / res_plain.max(1) as f64;
    println!(
        "residual (e3sm x {steps} steps, K={keyint}, nrmse:1e-3): payload {res_plain} B \
         plain -> {res_auto} B auto ({:.1}% smaller) | CR {cr_plain:.1} -> {cr_auto:.1}",
        100.0 * saving
    );

    let report = json::obj(vec![
        ("scale", json::s(if smoke { "smoke" } else { "bench" })),
        ("threads", json::num(num_threads() as f64)),
        ("n_symbols", json::num(n_syms as f64)),
        ("raw_mb", json::num(raw_mb)),
        (
            "huffman",
            json::obj(vec![
                ("encode_mb_s", json::num(raw_mb / enc_s)),
                ("decode_mb_s", json::num(raw_mb / dec_s)),
                ("decode_bitwise_mb_s", json::num(raw_mb / dec_bitwise_s)),
                ("decode_speedup_vs_bitwise", json::num(dec_bitwise_s / dec_s)),
            ]),
        ),
        (
            "rans",
            json::obj(vec![
                ("encode_mb_s", json::num(raw_mb / rans_enc_s)),
                ("decode_mb_s", json::num(raw_mb / rans_dec_s)),
                ("huffman_lut_decode_mb_s", json::num(raw_mb / dense_huff_dec_s)),
                ("decode_speedup_vs_huffman_lut", json::num(rans_speedup)),
                ("dense_bytes", json::num(dense_rans.len() as f64)),
                ("dense_huffman_bytes", json::num(dense_huff.len() as f64)),
                (
                    "size_ratio_vs_huffman",
                    json::num(dense_rans.len() as f64 / dense_huff.len() as f64),
                ),
            ]),
        ),
        (
            "container",
            json::obj(vec![
                ("plain_bytes", json::num(plain.len() as f64)),
                ("zero_run_bytes", json::num(zrun.len() as f64)),
                (
                    "zero_run_saving",
                    json::num(1.0 - zrun.len() as f64 / plain.len() as f64),
                ),
                ("zero_run_decode_mb_s", json::num(raw_mb / zrun_dec_s)),
            ]),
        ),
        (
            "obs_overhead",
            json::obj(vec![
                ("decode_off_mb_s", json::num(raw_mb / off_s)),
                ("decode_on_mb_s", json::num(raw_mb / on_s)),
                ("overhead_ratio", json::num(obs_ratio)),
                ("budget_ratio", json::num(obs_budget)),
            ]),
        ),
        (
            "residual",
            json::obj(vec![
                ("dataset", json::s("e3sm")),
                ("dims", json::arr_usize(&cfg.dims)),
                ("steps", json::num(steps as f64)),
                ("keyint", json::num(keyint as f64)),
                ("bound", json::s("nrmse:1e-3")),
                ("payload_plain_bytes", json::num(res_plain as f64)),
                ("payload_auto_bytes", json::num(res_auto as f64)),
                ("residual_saving", json::num(saving)),
                ("total_payload_plain_bytes", json::num(tot_plain as f64)),
                ("total_payload_auto_bytes", json::num(tot_auto as f64)),
                ("cr_plain", json::num(cr_plain)),
                ("cr_auto", json::num(cr_auto)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_coder.json", report.to_string_pretty())
        .expect("write BENCH_coder.json");
    println!("wrote BENCH_coder.json");
    std::fs::remove_dir_all(&dir).ok();
}
