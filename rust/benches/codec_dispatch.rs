//! Trait-object dispatch overhead of the unified `Codec` API on the
//! SZ3-like hot path. Run: `cargo bench --bench codec_dispatch`.
//!
//! Three variants over the same field + bound:
//!   1. `Sz3Like::new(eps).compress` — the raw pre-codec entry point
//!   2. `Sz3Codec` called through the concrete type (static dispatch)
//!   3. the same value behind `Box<dyn Codec>` (vtable dispatch)
//!
//! Compression runs millions of point predictions per call, so one
//! virtual call + archive assembly must be (and is) noise; the printed
//! ratio makes that visible in CI logs.

use attn_reduce::baselines::Sz3Like;
use attn_reduce::codec::{Codec, ErrorBound, Sz3Codec};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale};
use attn_reduce::data;
use attn_reduce::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    let cfg = dataset_preset(DatasetKind::E3sm, Scale::Smoke);
    let field = data::generate(&cfg);
    let bytes_raw = (field.len() * 4) as f64;
    let eps = 1e-3 * field.range();
    let bound = ErrorBound::PointwiseAbs(eps as f64);

    // 1. raw struct call
    let raw = Sz3Like::new(eps);
    b.run_items("dispatch/raw Sz3Like::compress", bytes_raw, || {
        black_box(raw.compress(black_box(&field)).unwrap());
    });

    // 2. concrete codec (static dispatch, includes archive assembly)
    let concrete = Sz3Codec::new(cfg.clone());
    b.run_items("dispatch/concrete Sz3Codec", bytes_raw, || {
        black_box(concrete.compress(black_box(&field), &bound).unwrap());
    });

    // 3. trait object (dynamic dispatch)
    let boxed: Box<dyn Codec> = Box::new(Sz3Codec::new(cfg.clone()));
    b.run_items("dispatch/Box<dyn Codec>", bytes_raw, || {
        black_box(boxed.compress(black_box(&field), &bound).unwrap());
    });

    // decompress side, same three shapes
    let archive = boxed.compress(&field, &bound).unwrap();
    let sz3_bytes = archive.section("SZ3B").unwrap().to_vec();
    b.run_items("dispatch/raw Sz3Like::decompress", bytes_raw, || {
        black_box(Sz3Like::decompress(black_box(&sz3_bytes)).unwrap());
    });
    b.run_items("dispatch/Box<dyn Codec> decompress", bytes_raw, || {
        black_box(boxed.decompress(black_box(&archive)).unwrap());
    });

    // headline number: dyn-dispatch cost relative to the raw call
    let raw_ns = b.results.iter().find(|s| s.name.contains("raw Sz3Like::compress"));
    let dyn_ns = b
        .results
        .iter()
        .find(|s| s.name.contains("Box<dyn Codec>") && !s.name.contains("decompress"));
    if let (Some(r), Some(d)) = (raw_ns, dyn_ns) {
        println!(
            "\ntrait-object overhead on compress: {:+.2}% (raw {:.3} ms, dyn {:.3} ms)",
            (d.mean_ns / r.mean_ns - 1.0) * 100.0,
            r.mean_ns / 1e6,
            d.mean_ns / 1e6
        );
    }
    b.write_csv("results/bench/codec_dispatch.csv").unwrap();
}
