//! Serving-layer throughput: request rate and latency percentiles for
//! `(step, region)` extraction over HTTP, cold (cache misses decode the
//! keyframe) vs warm (hits pay only the residual chain). The keyframe
//! payload accounting in the report is the acceptance criterion made
//! measurable: the warm pass must decode zero keyframe payload bytes.
//! Emits `BENCH_serve.json` next to the CWD.
//!
//! Run: `cargo bench --bench serve_throughput`
//! (`--smoke` or `BENCH_FAST=1` shrinks to smoke scale for CI.)

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use attn_reduce::codec::{Codec, ErrorBound, Sz3Codec};
use attn_reduce::config::{stream_frame_preset, DatasetKind, Scale};
use attn_reduce::data::timeseries;
use attn_reduce::obs;
use attn_reduce::serve::{ServeConfig, Server};
use attn_reduce::stream::StreamWriter;
use attn_reduce::util::json::{self, Value};
use attn_reduce::util::parallel::num_threads;

/// One GET; returns (body bytes, keyframe payload bytes this request
/// decoded, latency in µs).
fn get(addr: SocketAddr, target: &str) -> (usize, usize, f64) {
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(format!("GET {target} HTTP/1.1\r\nhost: bench\r\n\r\n").as_bytes())
        .expect("write");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read");
    let us = t0.elapsed().as_secs_f64() * 1e6;
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response header");
    let head = String::from_utf8_lossy(&raw[..split]).into_owned();
    assert!(head.starts_with("HTTP/1.1 200"), "request failed: {head}");
    let kf_bytes = head
        .lines()
        .find_map(|l| l.strip_prefix("x-keyframe-payload-bytes: "))
        .map(|v| v.trim().parse().expect("kf header"))
        .unwrap_or(0);
    (raw.len() - split - 4, kf_bytes, us)
}

fn get_body(addr: SocketAddr, target: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(format!("GET {target} HTTP/1.1\r\nhost: bench\r\n\r\n").as_bytes())
        .expect("write");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read");
    let split = raw.windows(4).position(|w| w == b"\r\n\r\n").expect("split");
    String::from_utf8_lossy(&raw[split + 4..]).into_owned()
}

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let i = ((sorted_us.len() - 1) as f64 * p).round() as usize;
    sorted_us[i]
}

fn pass(addr: SocketAddr, targets: &[String]) -> (Vec<f64>, usize, usize, f64) {
    let t0 = Instant::now();
    let mut lat = Vec::with_capacity(targets.len());
    let (mut bytes, mut kf) = (0usize, 0usize);
    for t in targets {
        let (b, k, us) = get(addr, t);
        bytes += b;
        kf += k;
        lat.push(us);
    }
    let secs = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lat, bytes, kf, secs)
}

fn main() {
    let smoke = std::env::var_os("BENCH_FAST").is_some()
        || std::env::args().any(|a| a == "--smoke");
    let (scale, steps, warm_rounds) = if smoke {
        (Scale::Smoke, 16usize, 3usize)
    } else {
        (Scale::Bench, 64, 10)
    };
    std::env::set_var("ATTN_REDUCE_QUIET", "1");

    // fixture: one sz3 stream, keyint 4 (every request chains residuals)
    let cfg = stream_frame_preset(DatasetKind::E3sm, scale);
    let codec = Sz3Codec::new(cfg.clone());
    let bound = ErrorBound::Nrmse(1e-3);
    let frames = timeseries::generate_frames(&cfg.dims, cfg.seed, 0, steps);
    let dir = std::env::temp_dir().join("attn_reduce_serve_bench");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    let path = dir.join("bench.tstr");
    let mut w =
        StreamWriter::create(&path, codec.id(), cfg.clone(), bound, 4).expect("create stream");
    w.append_frames(&codec, &frames).expect("append");
    w.finish().expect("finish");

    let server = Server::bind(ServeConfig::new(&dir, "127.0.0.1:0")).expect("bind");
    let addr = server.local_addr();
    let stop = server.stop_handle();
    let thread = std::thread::spawn(move || server.run().expect("serve"));
    println!(
        "serve_throughput: e3sm {:?} x {steps} steps on {addr}, {} threads",
        cfg.dims,
        num_threads()
    );

    // request mix: a corner quarter-region of every step — distinct
    // (keyframe, region) classes cold, all cached warm
    let region: String = cfg
        .dims
        .iter()
        .map(|&d| format!("0:{}", (d / 4).max(1)))
        .collect::<Vec<_>>()
        .join(",");
    let targets: Vec<String> = (0..steps)
        .map(|s| format!("/v1/streams/bench.tstr/extract?step={s}&region={region}"))
        .collect();

    let (cold_lat, cold_bytes, cold_kf, cold_secs) = pass(addr, &targets);
    println!(
        "cold: {} req in {cold_secs:.2}s ({:.0} req/s), p50 {:.0}µs p99 {:.0}µs, \
         {cold_kf} keyframe payload bytes decoded",
        targets.len(),
        targets.len() as f64 / cold_secs,
        percentile(&cold_lat, 0.50),
        percentile(&cold_lat, 0.99),
    );

    let mut warm_lat = Vec::new();
    let (mut warm_bytes, mut warm_kf, mut warm_secs) = (0usize, 0usize, 0.0f64);
    for _ in 0..warm_rounds {
        let (lat, bytes, kf, secs) = pass(addr, &targets);
        warm_lat.extend(lat);
        warm_bytes += bytes;
        warm_kf += kf;
        warm_secs += secs;
    }
    warm_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let warm_n = targets.len() * warm_rounds;
    println!(
        "warm: {warm_n} req in {warm_secs:.2}s ({:.0} req/s), p50 {:.0}µs p99 {:.0}µs, \
         {warm_kf} keyframe payload bytes decoded",
        warm_n as f64 / warm_secs,
        percentile(&warm_lat, 0.50),
        percentile(&warm_lat, 0.99),
    );
    assert_eq!(
        warm_kf, 0,
        "warm requests must serve keyframes from the cache (region_cost accounting)"
    );

    // cache effectiveness straight from the server's own counters
    let stats = get_body(addr, "/v1/stats");
    let hit_rate = stats
        .lines()
        .find_map(|l| l.trim().strip_prefix("\"hit_rate\": "))
        .map(|v| v.trim_end_matches(',').parse::<f64>().expect("hit_rate"))
        .unwrap_or(0.0);
    println!("server cache hit rate: {hit_rate:.3}");

    // one extra traced warm pass: its spans become the sample Chrome
    // trace that CI uploads. Kept out of the measured passes above so
    // the event-buffer cost never skews the trajectory numbers.
    obs::trace::start_tracing();
    let _ = pass(addr, &targets);

    stop.stop();
    thread.join().expect("server thread");

    match obs::trace::finish_trace(std::path::Path::new("BENCH_serve_trace.json")) {
        Ok(n) => println!("wrote BENCH_serve_trace.json ({n} spans)"),
        Err(e) => println!("trace write failed: {e}"),
    }

    // per-stage span accounting from the global registry: where request
    // wall time went, by pipeline stage (stages the fixture never
    // exercised are dropped rather than reported as zeros)
    let stages: Vec<Value> = obs::stages::all()
        .iter()
        .map(|t| (t, t.hist()))
        .filter(|(_, h)| h.count() > 0)
        .map(|(t, h)| {
            json::obj(vec![
                ("stage", json::s(t.name())),
                ("count", json::num(h.count() as f64)),
                ("sum_s", json::num(h.sum_scaled())),
                ("p50_s", json::num(h.quantile(0.50))),
                ("p99_s", json::num(h.quantile(0.99))),
            ])
        })
        .collect();
    println!("stage span aggregates: {} stages active", stages.len());

    let report = json::obj(vec![
        ("dataset", json::s("e3sm")),
        ("scale", json::s(if smoke { "smoke" } else { "bench" })),
        ("dims", json::arr_usize(&cfg.dims)),
        ("steps", json::num(steps as f64)),
        ("keyint", json::num(4.0)),
        ("bound", json::s(bound.to_string())),
        ("threads", json::num(num_threads() as f64)),
        ("region", json::s(region)),
        (
            "cold",
            json::obj(vec![
                ("requests", json::num(targets.len() as f64)),
                ("requests_per_s", json::num(targets.len() as f64 / cold_secs)),
                ("p50_us", json::num(percentile(&cold_lat, 0.50))),
                ("p99_us", json::num(percentile(&cold_lat, 0.99))),
                ("body_bytes", json::num(cold_bytes as f64)),
                ("keyframe_payload_bytes", json::num(cold_kf as f64)),
            ]),
        ),
        (
            "warm",
            json::obj(vec![
                ("requests", json::num(warm_n as f64)),
                ("requests_per_s", json::num(warm_n as f64 / warm_secs)),
                ("p50_us", json::num(percentile(&warm_lat, 0.50))),
                ("p99_us", json::num(percentile(&warm_lat, 0.99))),
                ("body_bytes", json::num(warm_bytes as f64)),
                ("keyframe_payload_bytes", json::num(warm_kf as f64)),
            ]),
        ),
        ("cache_hit_rate", json::num(hit_rate)),
        ("stages", Value::Arr(stages)),
    ]);
    std::fs::write("BENCH_serve.json", report.to_string_pretty())
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
    std::fs::remove_dir_all(&dir).ok();
}
