//! Streaming compression of the E3SM-like climate field through the L3
//! coordinator, routed through the unified codec: pipelined gather →
//! PJRT → entropy/scatter stages over bounded channels, producing the
//! same self-describing archive as the one-shot path.
//!
//! Demonstrates the backpressure design: a queue depth of 0 (rendezvous)
//! serializes the stages; deeper queues let the gather and sink stages
//! overlap with PJRT execution.
//!
//! ```sh
//! cargo run --release --example climate_stream [-- --steps 150]
//! ```

use std::rc::Rc;

use attn_reduce::codec::{archive_stats, Codec, CodecBuilder, ErrorBound};
use attn_reduce::compressor::nrmse;
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::runtime::Runtime;
use attn_reduce::util::cli::Args;

fn main() -> attn_reduce::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;

    let rt = Rc::new(Runtime::open("artifacts")?);
    let dataset = dataset_preset(DatasetKind::E3sm, Scale::Bench);

    println!("== climate_stream: E3SM PSL surrogate, streaming coordinator ==");
    let field = data::generate(&dataset);
    println!(
        "field {:?} ({:.1} MB), range [{:.0}, {:.0}] Pa",
        dataset.dims,
        (field.len() * 4) as f64 / 1e6,
        field.min(),
        field.max()
    );

    let mut builder = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Bench)
        .ckpt_dir("results/ckpt")
        .train(TrainConfig { steps: args.get_usize("steps", 150)?, ..TrainConfig::default() });
    let codec = builder.build_hier(DatasetKind::E3sm, &field)?;

    let bound = ErrorBound::Nrmse(1e-3);
    println!("\n-- queue-depth sweep (backpressure tuning, bound {bound}) --");
    for depth in [0usize, 1, 2, 4, 8] {
        let (_, stats) = codec.compress_streaming(&field, &bound, depth)?;
        println!("queue={depth}: {}", stats.summary());
    }

    // correctness cross-check (AE-only, GAE off, so the comparison is
    // exact): the streamed archive decodes to the sequential recon
    let (archive_stream, _) = codec.compress_streaming(&field, &ErrorBound::None, 4)?;
    let (archive_seq, recon_seq) = codec.compress_with_recon(&field, &ErrorBound::None)?;
    let recon_stream = codec.decompress(&archive_stream)?;
    let max_d = recon_seq
        .data()
        .iter()
        .zip(recon_stream.data())
        .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
    let s = archive_stats(&archive_stream)?;
    println!(
        "\nstreamed archive: CR = {:.1}, NRMSE = {:.3e}, max |stream - seq| = {max_d:.3e}",
        s.cr,
        nrmse(&field, &recon_stream)
    );
    println!(
        "sequential archive bytes = {}, streamed = {}",
        archive_seq.total_bytes(),
        archive_stream.total_bytes()
    );
    assert!(max_d <= 1e-4 * field.range(), "stream vs sequential differ by {max_d}");
    Ok(())
}
