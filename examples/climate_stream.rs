//! Streaming compression of the E3SM-like climate field through the L3
//! coordinator: pipelined gather → PJRT → entropy/scatter stages over
//! bounded channels, with per-stage busy times and end-to-end throughput.
//!
//! Demonstrates the backpressure design: a queue depth of 0 (rendezvous)
//! serializes the stages; deeper queues let the gather and sink stages
//! overlap with PJRT execution.
//!
//! ```sh
//! cargo run --release --example climate_stream [-- --steps 150]
//! ```

use attn_reduce::compressor::{nrmse, HierCompressor};
use attn_reduce::config::{dataset_preset, model_preset, DatasetKind, PipelineConfig, Scale};
use attn_reduce::coordinator::stream_compress;
use attn_reduce::data::{self, Normalizer};
use attn_reduce::runtime::Runtime;
use attn_reduce::util::cli::Args;

fn main() -> attn_reduce::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;

    let rt = Runtime::open("artifacts")?;
    let mut cfg = PipelineConfig {
        dataset: dataset_preset(DatasetKind::E3sm, Scale::Bench),
        model: model_preset(DatasetKind::E3sm),
        train: Default::default(),
        tau: 0.0,
    };
    cfg.train.steps = args.get_usize("steps", 150)?;

    println!("== climate_stream: E3SM PSL surrogate, streaming coordinator ==");
    let field = data::generate(&cfg.dataset);
    println!(
        "field {:?} ({:.1} MB), range [{:.0}, {:.0}] Pa",
        cfg.dataset.dims,
        (field.len() * 4) as f64 / 1e6,
        field.min(),
        field.max()
    );

    let ckpt = std::path::PathBuf::from("results/ckpt");
    std::fs::create_dir_all(&ckpt)?;
    let (comp, reports) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field)?;
    for r in &reports {
        println!("trained {}", r.summary());
    }

    println!("\n-- queue-depth sweep (backpressure tuning) --");
    for depth in [0usize, 1, 2, 4, 8] {
        let out = stream_compress(&comp, &field, depth)?;
        println!("queue={depth}: {}", out.stats.summary());
    }

    // correctness cross-check against the sequential path
    let out = stream_compress(&comp, &field, 4)?;
    let stats = Normalizer::fit(cfg.dataset.normalization, &field);
    let mut recon = out.recon;
    Normalizer::invert(&stats, &mut recon);
    println!(
        "\nstreamed AE reconstruction NRMSE = {:.3e} (quantized latents: {} HBAE, {} BAE codes)",
        nrmse(&field, &recon),
        out.lh_codes.len(),
        out.lb_codes.len()
    );
    Ok(())
}
