//! End-to-end driver: exercises the whole three-layer stack on a
//! realistic workload through the unified codec API.
//!
//! * trains the HBAE (≈2.4 M params) + BAE for a few hundred Adam steps
//!   through the AOT `train_step` artifacts (L2/L1 fwd+bwd on PJRT),
//!   logging the loss curve,
//! * compresses the bench-scale multi-species combustion field at several
//!   typed NRMSE bounds, reporting CR / NRMSE per bound,
//! * restores each archive from its serialized bytes alone (header-driven
//!   codec reconstruction) and re-verifies the guarantee.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! cargo run --release --example e2e_s3d [-- --steps 300]
//! ```

use std::rc::Rc;

use attn_reduce::codec::{archive_stats, Codec, CodecBuilder, ErrorBound, HierCodec};
use attn_reduce::compressor::{mean_channel_nrmse, Archive, HierCompressor};
use attn_reduce::config::{dataset_preset, model_preset, DatasetKind, PipelineConfig, Scale};
use attn_reduce::data;
use attn_reduce::linalg::norm2_f32;
use attn_reduce::model::ParamStore;
use attn_reduce::runtime::Runtime;
use attn_reduce::tensor::{block_origins, extract_block};
use attn_reduce::util::cli::Args;

fn main() -> attn_reduce::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let steps = args.get_usize("steps", 300)?;

    let rt = Rc::new(Runtime::open("artifacts")?);
    let mut cfg = PipelineConfig {
        dataset: dataset_preset(DatasetKind::S3d, Scale::Bench),
        model: model_preset(DatasetKind::S3d),
        train: Default::default(),
        tau: 0.0,
    };
    cfg.train.steps = steps;
    cfg.train.log_every = 20;

    println!("== e2e_s3d: bench-scale S3D surrogate ==");
    let t0 = std::time::Instant::now();
    let field = data::generate(&cfg.dataset);
    println!(
        "generated {:?} ({:.1} MB) in {:.1}s",
        cfg.dataset.dims,
        (field.len() * 4) as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );

    // --- train (fresh every run: this example IS the training demo) ---
    let ckpt = std::path::PathBuf::from("results/ckpt-e2e");
    std::fs::create_dir_all(&ckpt)?;
    std::fs::remove_file(ParamStore::default_path(&ckpt, &cfg.model.hbae_group)).ok();
    std::fs::remove_file(ParamStore::default_path(&ckpt, &cfg.model.bae_group)).ok();
    let (comp, reports) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field)?;
    println!("\n-- loss curves --");
    for r in &reports {
        println!("{}:", r.group);
        for &(s, l) in &r.losses {
            println!("  step {s:>4}: {l:.4e}");
        }
        println!("  ({:.1}s, {:.2} steps/s)", r.wall_s, r.steps as f64 / r.wall_s);
    }
    let codec = HierCodec::new(comp);
    let mut builder = CodecBuilder::new().runtime(rt.clone()).ckpt_dir(&ckpt);

    // --- compress across typed bounds ---
    println!("\n-- compression sweep (paper-accounting CR) --");
    println!(
        "{:>12} {:>10} {:>10} {:>12} {:>10}",
        "bound", "CR", "CR(all)", "meanNRMSE", "GCOF"
    );
    let dataset = &cfg.dataset;
    let d = dataset.gae_block_len();
    for target in [3e-3f64, 1e-3, 3e-4, 1e-4] {
        let bound = ErrorBound::Nrmse(target);
        let (archive, recon) = codec.compress_with_recon(&field, &bound)?;
        let stats = archive_stats(&archive)?;
        let e = mean_channel_nrmse(&field, &recon);
        let gcof = archive.section("GCOF").map(|b| b.len()).unwrap_or(0);
        println!(
            "{:>12} {:>10.1} {:>10.1} {e:>12.3e} {gcof:>9}B",
            bound.to_string(),
            stats.cr,
            stats.cr_total
        );

        // verify the bound via a header-driven restore of the serialized
        // archive (no preset flags, no manual checkpoint plumbing)
        let archive2 = Archive::from_bytes(&archive.to_bytes())?;
        let recon2 = builder.for_archive(&archive2)?.decompress(&archive2)?;
        let tau = bound.gae_tau(dataset, field.range() as f64);
        let origins = block_origins(&dataset.dims, &dataset.gae_block);
        let (mut a, mut b) = (vec![0f32; d], vec![0f32; d]);
        let mut worst: f64 = 0.0;
        for o in &origins {
            extract_block(&field, o, &dataset.gae_block, &mut a);
            extract_block(&recon2, o, &dataset.gae_block, &mut b);
            let diff: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
            worst = worst.max(norm2_f32(&diff) / tau as f64);
        }
        assert!(worst <= 1.001, "bound violated: {worst}");
    }

    println!("\n-- runtime execution stats --");
    let mut stats = rt.all_stats();
    stats.sort_by(|a, b| a.0.cmp(&b.0));
    for (name, s) in stats {
        if s.calls > 0 {
            println!(
                "  {name:<34} {:>6} calls, {:>8.2} ms avg",
                s.calls,
                s.total_us as f64 / s.calls as f64 / 1e3
            );
        }
    }
    println!("\ntotal wall time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
