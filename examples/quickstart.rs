//! Quickstart: train the hierarchical compressor on a small synthetic
//! S3D-like field, compress with a guaranteed error bound, decompress,
//! and verify the bound. (~1 minute on a laptop-class CPU.)
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use attn_reduce::compressor::{nrmse, HierCompressor};
use attn_reduce::config::{dataset_preset, model_preset, DatasetKind, PipelineConfig, Scale};
use attn_reduce::data;
use attn_reduce::linalg::norm2_f32;
use attn_reduce::runtime::Runtime;
use attn_reduce::tensor::{block_origins, extract_block};

fn main() -> attn_reduce::Result<()> {
    // 1. open the AOT artifacts (python never runs from here on)
    let rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    // 2. a small synthetic multi-species combustion field (16 species
    //    with strong inter-species correlation — the structure the
    //    hyper-block attention exploits)
    let mut cfg = PipelineConfig {
        dataset: dataset_preset(DatasetKind::S3d, Scale::Smoke),
        model: model_preset(DatasetKind::S3d),
        train: Default::default(),
        tau: 0.0,
    };
    cfg.train.steps = 60;
    let field = data::generate(&cfg.dataset);
    println!(
        "field: {:?} = {} points ({:.1} MB)",
        cfg.dataset.dims,
        field.len(),
        (field.len() * 4) as f64 / 1e6
    );

    // 3. train HBAE + BAE (cached under results/ckpt-quickstart)
    let ckpt = std::path::PathBuf::from("results/ckpt-quickstart");
    std::fs::create_dir_all(&ckpt)?;
    let (comp, reports) = HierCompressor::prepare(&rt, &cfg, &ckpt, &field)?;
    for r in &reports {
        println!("trained {}", r.summary());
    }

    // 4. compress with a per-block l2 bound targeting NRMSE 1e-3
    let tau = PipelineConfig::tau_for_nrmse(
        1e-3,
        field.range() as f64,
        cfg.dataset.gae_block_len(),
    );
    let (archive, recon) = comp.compress(&field, tau)?;
    let stats = comp.stats(&archive);
    println!(
        "compressed: CR = {:.1} (paper accounting) / {:.1} (all bytes), NRMSE = {:.3e}",
        stats.cr,
        stats.cr_total,
        nrmse(&field, &recon)
    );

    // 5. verify the guarantee: EVERY GAE block satisfies ||err||_2 <= tau
    let d = cfg.dataset.gae_block_len();
    let origins = block_origins(&cfg.dataset.dims, &cfg.dataset.gae_block);
    let mut worst: f64 = 0.0;
    let (mut a, mut b) = (vec![0f32; d], vec![0f32; d]);
    for o in &origins {
        extract_block(&field, o, &cfg.dataset.gae_block, &mut a);
        extract_block(&recon, o, &cfg.dataset.gae_block, &mut b);
        let diff: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
        worst = worst.max(norm2_f32(&diff) / tau as f64);
    }
    println!(
        "error-bound check: worst block ||err||/tau = {worst:.3} over {} blocks {}",
        origins.len(),
        if worst <= 1.0 { "— GUARANTEED ✓" } else { "— VIOLATED ✗" }
    );
    assert!(worst <= 1.001);
    Ok(())
}
