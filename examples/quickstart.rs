//! Quickstart: build the hierarchical codec through `CodecBuilder`, train
//! on a small synthetic S3D-like field, compress with a typed error
//! bound, restore from the archive header alone, and verify the
//! guarantee. (~1 minute on a laptop-class CPU.)
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use attn_reduce::codec::{archive_stats, Codec, CodecBuilder, ErrorBound};
use attn_reduce::compressor::{nrmse, Archive};
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::linalg::norm2_f32;
use attn_reduce::runtime::Runtime;
use attn_reduce::tensor::{block_origins, extract_block};

fn main() -> attn_reduce::Result<()> {
    // 1. open the AOT artifacts (python never runs from here on)
    let rt = Rc::new(Runtime::open("artifacts")?);
    println!("PJRT platform: {}", rt.platform());

    // 2. a small synthetic multi-species combustion field (16 species
    //    with strong inter-species correlation — the structure the
    //    hyper-block attention exploits)
    let dataset = dataset_preset(DatasetKind::S3d, Scale::Smoke);
    let field = data::generate(&dataset);
    println!(
        "field: {:?} = {} points ({:.1} MB)",
        dataset.dims,
        field.len(),
        (field.len() * 4) as f64 / 1e6
    );

    // 3. one builder resolves presets, checkpoints, and the runtime;
    //    training runs once and is cached under results/ckpt-quickstart
    let mut builder = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Smoke)
        .ckpt_dir("results/ckpt-quickstart")
        .train(TrainConfig { steps: 60, ..TrainConfig::default() });
    let codec = builder.build_hier(DatasetKind::S3d, &field)?;

    // 4. compress with a typed bound: dataset NRMSE <= 1e-3 (Eq. 11 maps
    //    it onto the per-GAE-block l2 tau the pipeline guarantees)
    let bound = ErrorBound::Nrmse(1e-3);
    let (archive, recon) = codec.compress_with_recon(&field, &bound)?;
    let stats = archive_stats(&archive)?;
    println!(
        "compressed under {bound}: CR = {:.1} (paper accounting) / {:.1} (all bytes), NRMSE = {:.3e}",
        stats.cr,
        stats.cr_total,
        nrmse(&field, &recon)
    );

    // 5. restore from the serialized bytes alone — the archive header
    //    names the codec, dataset, and model groups
    let archive2 = Archive::from_bytes(&archive.to_bytes())?;
    let restored = builder.for_archive(&archive2)?.decompress(&archive2)?;

    // 6. verify the guarantee: EVERY GAE block satisfies ||err||_2 <= tau
    let tau = bound.gae_tau(&dataset, field.range() as f64);
    let d = dataset.gae_block_len();
    let origins = block_origins(&dataset.dims, &dataset.gae_block);
    let mut worst: f64 = 0.0;
    let (mut a, mut b) = (vec![0f32; d], vec![0f32; d]);
    for o in &origins {
        extract_block(&field, o, &dataset.gae_block, &mut a);
        extract_block(&restored, o, &dataset.gae_block, &mut b);
        let diff: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
        worst = worst.max(norm2_f32(&diff) / tau as f64);
    }
    println!(
        "error-bound check: worst block ||err||/tau = {worst:.3} over {} blocks {}",
        origins.len(),
        if worst <= 1.0 { "— GUARANTEED ✓" } else { "— VIOLATED ✗" }
    );
    assert!(worst <= 1.001);
    Ok(())
}
