//! XGC fusion use case: compress gyrokinetic velocity histograms while
//! preserving physics moments, through the unified codec API.
//!
//! The paper's error bound is an ℓ2 guarantee per 39x39 histogram; this
//! example additionally reports what downstream plasma analysis cares
//! about — conservation of the distribution moments (density, parallel
//! flow, temperature) through compression — which the ℓ2 bound implies
//! but the paper leaves implicit.
//!
//! ```sh
//! cargo run --release --example xgc_histograms [-- --steps 150]
//! ```

use std::rc::Rc;

use attn_reduce::codec::{archive_stats, Codec, CodecBuilder, ErrorBound};
use attn_reduce::compressor::nrmse;
use attn_reduce::config::{dataset_preset, DatasetKind, Scale, TrainConfig};
use attn_reduce::data;
use attn_reduce::runtime::Runtime;
use attn_reduce::util::cli::Args;

/// Velocity-space moments of one [nvx, nvy] histogram.
fn moments(h: &[f32], nvx: usize, nvy: usize) -> (f64, f64, f64) {
    let mut n = 0.0f64;
    let mut flow = 0.0f64;
    for ix in 0..nvx {
        let vx = ix as f64 / (nvx - 1) as f64 - 0.5;
        for iy in 0..nvy {
            let f = h[ix * nvy + iy] as f64;
            n += f;
            flow += f * vx;
        }
    }
    let u = if n.abs() > 1e-30 { flow / n } else { 0.0 };
    let mut temp = 0.0f64;
    for ix in 0..nvx {
        let vx = ix as f64 / (nvx - 1) as f64 - 0.5;
        for iy in 0..nvy {
            temp += h[ix * nvy + iy] as f64 * (vx - u) * (vx - u);
        }
    }
    (n, u, if n.abs() > 1e-30 { temp / n } else { 0.0 })
}

fn main() -> attn_reduce::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;

    let rt = Rc::new(Runtime::open("artifacts")?);
    let dataset = dataset_preset(DatasetKind::Xgc, Scale::Bench);

    println!("== xgc_histograms: gyrokinetic F-data surrogate ==");
    let field = data::generate(&dataset);
    let dims = dataset.dims.clone();
    println!("field {dims:?} ({:.1} MB)", (field.len() * 4) as f64 / 1e6);

    let mut builder = CodecBuilder::new()
        .runtime(rt)
        .scale(Scale::Bench)
        .ckpt_dir("results/ckpt")
        .train(TrainConfig { steps: args.get_usize("steps", 150)?, ..TrainConfig::default() });
    let codec = builder.build_hier(DatasetKind::Xgc, &field)?;

    let bound = ErrorBound::Nrmse(1e-3);
    let (archive, recon) = codec.compress_with_recon(&field, &bound)?;
    let stats = archive_stats(&archive)?;
    println!(
        "\nbound {bound}: CR = {:.1} (paper accounting), NRMSE = {:.3e}",
        stats.cr,
        nrmse(&field, &recon)
    );

    // moment preservation across all histograms
    let (planes, nodes, nvx, nvy) = (dims[0], dims[1], dims[2], dims[3]);
    let hist = nvx * nvy;
    let mut worst = (0.0f64, 0.0f64, 0.0f64);
    for p in 0..planes {
        for nd in 0..nodes {
            let off = (p * nodes + nd) * hist;
            let (n0, u0, t0) = moments(&field.data()[off..off + hist], nvx, nvy);
            let (n1, u1, t1) = moments(&recon.data()[off..off + hist], nvx, nvy);
            worst.0 = worst.0.max(((n1 - n0) / n0.abs().max(1e-30)).abs());
            worst.1 = worst.1.max((u1 - u0).abs());
            worst.2 = worst.2.max(((t1 - t0) / t0.abs().max(1e-30)).abs());
        }
    }
    println!("moment preservation over {} histograms:", planes * nodes);
    println!("  max relative density error : {:.3e}", worst.0);
    println!("  max parallel-flow shift    : {:.3e}", worst.1);
    println!("  max relative T_par error   : {:.3e}", worst.2);
    assert!(worst.0 < 0.05, "density badly violated");
    Ok(())
}
